//! Shadow-audit sampling: live accuracy measurement for served variants.
//!
//! The manifest stamps each variant with a *static* `mape` measured at
//! export time; this module turns that into a **live error budget**. The
//! engine samples a configurable fraction of completed requests — the
//! sampling decision is a lock-free counter hash, allocation-free and
//! pinned by `tests/alloc_free.rs` — and copies `(input, served output)`
//! into a bounded drop-oldest queue. A dedicated audit worker then
//! re-solves each sample against the task's vector field with tight-tol
//! `dopri5_ws` in its own [`RkWorkspace`] (never the dispatch workers'),
//! and records:
//!
//! * relative terminal error into a per-(task, variant) log-bucket error
//!   histogram ([`LatencyHistogram`] reused at nano-relative-error = "ppb"
//!   scale) + an EWMA checked against the manifest `mape` budget —
//!   a *sustained* breach (EWMA > `breach_factor × mape` for
//!   `breach_streak` consecutive samples) increments the
//!   `audit_budget_breach` counter;
//! * the input states into a per-key [`DriftSketch`], scored against the
//!   manifest's `train_stats` stamp (absent ⇒ drift disabled, loudly).
//!
//! Dispatch never blocks on any of this: `offer` uses `try_lock` and a
//! drop-oldest policy, and every drop is counted.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::obs::drift::{DriftSketch, TrainStats};
use crate::runtime::manifest::Manifest;
use crate::runtime::native::NativeModel;
use crate::solvers::{dopri5_ws, AdaptiveOpts, RkWorkspace};
use crate::tensor::Tensor;
use crate::util::stats::LatencyHistogram;

/// Relative error is recorded into the log-bucket histogram in units of
/// 1e-9 ("ppb"): `record(err × 1e9 µs)`, read back via
/// `percentile_us(q) × 1e-9`. The histogram's 40 log₂ buckets then span
/// relative errors ~1e-9 ..= ~1e3 — far beyond both ends of any plausible
/// budget.
pub const ERR_SCALE: f64 = 1e9;

/// Audit-plane configuration, carried on
/// [`EngineConfig`](crate::coordinator::engine::EngineConfig) and set from
/// `hypersolverd serve --audit-rate R --audit-tol T`.
#[derive(Clone, Debug)]
pub struct AuditConfig {
    /// fraction of completed requests to audit (0.0 disables the plane)
    pub rate: f64,
    /// reference dopri5 tolerance for the re-solve
    pub tol: f32,
    /// bounded sample queue depth (drop-oldest beyond this)
    pub queue_cap: usize,
    /// EWMA smoothing factor for the measured error
    pub ewma_alpha: f64,
    /// budget headroom: breach condition is `ewma > breach_factor × mape`
    pub breach_factor: f64,
    /// consecutive breaching samples before the breach counter increments
    pub breach_streak: u32,
    /// sampler hash seed (same seed + request stream ⇒ same decisions)
    pub seed: u64,
}

impl Default for AuditConfig {
    fn default() -> Self {
        AuditConfig {
            rate: 0.0,
            tol: 1e-6,
            queue_cap: 256,
            ewma_alpha: 0.2,
            breach_factor: 2.0,
            breach_streak: 4,
            seed: 0x5EED_A0D1,
        }
    }
}

/// splitmix64 finalizer: decorrelates the sequential sample counter.
#[inline]
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// The sampling decision: a counter-indexed hash against a rate threshold.
/// Lock-free, allocation-free (pinned in `tests/alloc_free.rs`) and
/// deterministic — decision `i` depends only on `(seed, i)`, so the same
/// seed over the same request stream audits the same requests.
pub struct AuditSampler {
    seed: u64,
    /// `rate` mapped onto u64 range; 0 ⇒ never, `u64::MAX` ⇒ always
    threshold: u64,
    counter: AtomicU64,
}

impl AuditSampler {
    pub fn new(rate: f64, seed: u64) -> AuditSampler {
        let clamped = rate.clamp(0.0, 1.0);
        // float→int casts saturate, so rate 1.0 lands exactly on u64::MAX
        let threshold = (clamped * u64::MAX as f64) as u64;
        AuditSampler {
            seed,
            threshold,
            counter: AtomicU64::new(0),
        }
    }

    /// Should this completed request be audited? Hot-path safe.
    #[inline]
    pub fn decide(&self) -> bool {
        if self.threshold == 0 {
            return false;
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        self.threshold == u64::MAX
            || mix(self.seed ^ n.wrapping_mul(0x9e37_79b9_7f4a_7c15)) < self.threshold
    }

    /// decisions taken so far (sampled or not)
    pub fn decisions(&self) -> u64 {
        self.counter.load(Ordering::Relaxed)
    }
}

/// One sampled request: the served (input, output) pair plus the interned
/// (task, variant) key it ran under.
#[derive(Clone, Debug)]
pub struct AuditSample {
    /// interned key from `CoordinatorMetrics::stage_key`
    pub key: u32,
    pub rows: usize,
    pub dims: usize,
    /// request input block, row-major `rows × dims`
    pub input: Vec<f32>,
    /// served output, row-major (same layout when the task solves in
    /// state space; anything else is counted `unsupported`)
    pub served: Vec<f32>,
}

/// Mutable per-key audit state the worker owns.
struct KeyLive {
    ewma: Option<f64>,
    streak: u32,
    drift: DriftSketch,
}

/// Per-(task, variant) audit ledger.
pub struct KeyAudit {
    pub task: String,
    pub variant: String,
    /// the manifest `mape` stamp this key is held against
    pub budget: f64,
    train: Option<TrainStats>,
    /// measured relative error, log-bucketed at [`ERR_SCALE`]
    pub err: LatencyHistogram,
    pub samples: AtomicU64,
    pub breaches: AtomicU64,
    live: Mutex<KeyLive>,
}

impl KeyAudit {
    fn new(task: String, variant: String, budget: f64, dims: usize, train: Option<TrainStats>) -> KeyAudit {
        KeyAudit {
            task,
            variant,
            budget,
            train,
            err: LatencyHistogram::new(),
            samples: AtomicU64::new(0),
            breaches: AtomicU64::new(0),
            live: Mutex::new(KeyLive {
                ewma: None,
                streak: 0,
                drift: DriftSketch::new(dims),
            }),
        }
    }
}

/// Read-side snapshot of one key, consumed by `cmd:"health"` and the
/// Prometheus render.
#[derive(Clone, Debug)]
pub struct KeySnapshot {
    pub task: String,
    pub variant: String,
    pub samples: u64,
    pub err_p50: f64,
    pub err_p99: f64,
    pub err_mean: f64,
    pub ewma: Option<f64>,
    pub budget: f64,
    pub breaches: u64,
    pub has_train_stats: bool,
    pub drift_rows: u64,
    pub drift_score: Option<f64>,
}

impl KeySnapshot {
    /// `"ok"` / `"breach"` / `"no_samples"` — the health verdict string.
    pub fn budget_status(&self) -> &'static str {
        match self.ewma {
            None => "no_samples",
            Some(_) if self.breaches > 0 => "breach",
            Some(e) if e > self.budget => "over_budget",
            Some(_) => "ok",
        }
    }
}

/// Worker-owned solve state: one reference workspace + cached models.
struct WorkerState {
    ws: RkWorkspace,
    models: BTreeMap<String, NativeModel>,
}

/// The audit plane: bounded sample queue + per-key ledgers + the worker's
/// reference-solve state. Shared `Arc` between the engine (producer), the
/// audit worker (consumer) and the read surfaces.
pub struct AuditPlane {
    pub config: AuditConfig,
    pub sampler: AuditSampler,
    queue: Mutex<VecDeque<AuditSample>>,
    wake: Condvar,
    shutdown: AtomicBool,
    /// samples lost to a full queue or a contended offer
    pub drops: AtomicU64,
    /// samples accepted into the queue
    pub enqueued: AtomicU64,
    /// samples the worker could not re-solve (image readouts, stale keys…)
    pub unsupported: AtomicU64,
    keys: Mutex<BTreeMap<u32, KeyAudit>>,
    worker: Mutex<WorkerState>,
}

impl AuditPlane {
    pub fn new(config: AuditConfig) -> AuditPlane {
        let sampler = AuditSampler::new(config.rate, config.seed);
        AuditPlane {
            sampler,
            queue: Mutex::new(VecDeque::with_capacity(config.queue_cap.max(1))),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            drops: AtomicU64::new(0),
            enqueued: AtomicU64::new(0),
            unsupported: AtomicU64::new(0),
            keys: Mutex::new(BTreeMap::new()),
            worker: Mutex::new(WorkerState {
                ws: RkWorkspace::new(),
                models: BTreeMap::new(),
            }),
            config,
        }
    }

    /// Hand a sampled request to the plane. Never blocks dispatch: a
    /// contended queue lock or a full queue costs a drop counter tick (the
    /// full case drops the *oldest* sample so the queue tracks recent
    /// traffic), nothing else.
    pub fn offer(&self, sample: AuditSample) {
        let Ok(mut q) = self.queue.try_lock() else {
            self.drops.fetch_add(1, Ordering::Relaxed);
            return;
        };
        if q.len() >= self.config.queue_cap.max(1) {
            q.pop_front();
            self.drops.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(sample);
        self.enqueued.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.wake.notify_one();
    }

    /// Ask the worker to exit; `Engine::drop` pairs this with a join.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }

    pub fn is_shut_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Worker loop body: block (with a timeout so shutdown is prompt) until
    /// samples arrive, then drain them. `resolve` maps an interned key back
    /// to its (task, variant) names — the engine passes
    /// `CoordinatorMetrics::key_name`.
    pub fn run_worker<F: Fn(u32) -> Option<(String, String)>>(
        &self,
        manifest: &Manifest,
        resolve: F,
    ) {
        while !self.is_shut_down() {
            let sample = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if self.is_shut_down() {
                        return;
                    }
                    if let Some(s) = q.pop_front() {
                        break s;
                    }
                    let (guard, _) = self
                        .wake
                        .wait_timeout(q, Duration::from_millis(50))
                        .unwrap();
                    q = guard;
                }
            };
            self.process_sample(manifest, &resolve, sample);
        }
    }

    /// Synchronously drain everything queued right now; returns how many
    /// samples were processed. Tests and benches call this (via
    /// `Engine::audit_flush`) instead of racing the worker thread.
    pub fn process_pending<F: Fn(u32) -> Option<(String, String)>>(
        &self,
        manifest: &Manifest,
        resolve: F,
    ) -> usize {
        let mut done = 0;
        loop {
            let Some(sample) = self.queue.lock().unwrap().pop_front() else {
                return done;
            };
            self.process_sample(manifest, &resolve, sample);
            done += 1;
        }
    }

    /// Re-solve one sample at the reference tolerance and fold the result
    /// into the key's ledger.
    fn process_sample<F: Fn(u32) -> Option<(String, String)>>(
        &self,
        manifest: &Manifest,
        resolve: &F,
        sample: AuditSample,
    ) {
        let Some((task_name, variant_name)) = resolve(sample.key) else {
            self.unsupported.fetch_add(1, Ordering::Relaxed);
            return;
        };
        let Some(task) = manifest.tasks.get(&task_name) else {
            self.unsupported.fetch_add(1, Ordering::Relaxed);
            return;
        };
        // The reference solve integrates the raw state; image tasks serve
        // through learned augment/readout maps, so their (input, output)
        // pairs are not comparable in state space — counted, not guessed.
        let state_dims: usize = task.state_shape.iter().skip(1).product();
        if task.kind == "image"
            || sample.dims != state_dims.max(1)
            || sample.rows == 0
            || sample.input.len() != sample.rows * sample.dims
            || sample.served.len() != sample.input.len()
        {
            self.unsupported.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let budget = task
            .variant(&variant_name)
            .map(|v| v.mape)
            .unwrap_or(f64::INFINITY);

        let err = {
            let mut w = self.worker.lock().unwrap();
            let WorkerState { ws, models } = &mut *w;
            if !models.contains_key(&task_name) {
                match NativeModel::load(manifest, task) {
                    Ok(m) => {
                        models.insert(task_name.clone(), m);
                    }
                    Err(e) => {
                        crate::log_warn!("audit: cannot load model for {task_name}: {e}");
                        self.unsupported.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                }
            }
            let model = &models[&task_name];
            let z0 = match Tensor::new(&[sample.rows, sample.dims], sample.input.clone()) {
                Ok(t) => t,
                Err(_) => {
                    self.unsupported.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            };
            let opts = AdaptiveOpts::with_tol(self.config.tol);
            match dopri5_ws(model.field(), &z0, task.s_span, &opts, ws) {
                Ok(r) => relative_error(&sample.served, r.z.data(), sample.dims),
                Err(e) => {
                    crate::log_warn!("audit: reference solve failed for {task_name}: {e}");
                    self.unsupported.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        };
        // a non-finite relative error means the served output (or the
        // reference) went NaN/inf — the worst possible health event. Clamp
        // to a huge finite error so it saturates the top histogram bucket
        // and trips the budget machinery, instead of poisoning the EWMA.
        let err = if err.is_finite() { err } else { 1e12 };

        let mut keys = self.keys.lock().unwrap();
        let entry = keys.entry(sample.key).or_insert_with(|| {
            if task.train_stats.is_none() {
                crate::log_warn!(
                    "audit: task {task_name} has no train_stats stamp; drift reporting \
                     disabled for {task_name}/{variant_name} (re-export with a current \
                     hypertrain/hyperbench to enable)"
                );
            }
            KeyAudit::new(
                task_name.clone(),
                variant_name.clone(),
                budget,
                sample.dims,
                task.train_stats.clone(),
            )
        });
        entry.samples.fetch_add(1, Ordering::Relaxed);
        entry.err.record(Duration::from_micros(
            ((err * ERR_SCALE).round() as u64).max(1),
        ));
        let mut live = entry.live.lock().unwrap();
        let alpha = self.config.ewma_alpha.clamp(0.0, 1.0);
        let ewma = match live.ewma {
            Some(prev) => alpha * err + (1.0 - alpha) * prev,
            None => err,
        };
        live.ewma = Some(ewma);
        if ewma > self.config.breach_factor * entry.budget {
            live.streak += 1;
            if live.streak >= self.config.breach_streak.max(1) {
                entry.breaches.fetch_add(1, Ordering::Relaxed);
                live.streak = 0;
            }
        } else {
            live.streak = 0;
        }
        for row in sample.input.chunks_exact(sample.dims) {
            live.drift.observe_row(row);
        }
    }

    /// Snapshot every key's ledger, sorted by (task, variant) for a
    /// deterministic render order.
    pub fn snapshot(&self) -> Vec<KeySnapshot> {
        let keys = self.keys.lock().unwrap();
        let mut out: Vec<KeySnapshot> = keys
            .values()
            .map(|k| {
                let live = k.live.lock().unwrap();
                KeySnapshot {
                    task: k.task.clone(),
                    variant: k.variant.clone(),
                    samples: k.samples.load(Ordering::Relaxed),
                    err_p50: k.err.percentile_us(50.0) / ERR_SCALE,
                    err_p99: k.err.percentile_us(99.0) / ERR_SCALE,
                    err_mean: k.err.mean_us() / ERR_SCALE,
                    ewma: live.ewma,
                    budget: k.budget,
                    breaches: k.breaches.load(Ordering::Relaxed),
                    has_train_stats: k.train.is_some(),
                    drift_rows: live.drift.count(),
                    drift_score: k.train.as_ref().and_then(|t| live.drift.score(t)),
                }
            })
            .collect();
        out.sort_by(|a, b| (&a.task, &a.variant).cmp(&(&b.task, &b.variant)));
        out
    }

    /// queued-but-unprocessed samples (test/bench introspection)
    pub fn backlog(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

fn relative_error(served: &[f32], reference: &[f32], dims: usize) -> f64 {
    const EPS: f64 = 1e-12;
    let mut total = 0.0;
    let mut rows = 0usize;
    for (s_row, r_row) in served.chunks_exact(dims).zip(reference.chunks_exact(dims)) {
        let mut diff2 = 0.0f64;
        let mut ref2 = 0.0f64;
        for (s, r) in s_row.iter().zip(r_row) {
            let d = (*s as f64) - (*r as f64);
            diff2 += d * d;
            ref2 += (*r as f64) * (*r as f64);
        }
        total += diff2.sqrt() / (ref2.sqrt() + EPS);
        rows += 1;
    }
    if rows == 0 {
        0.0
    } else {
        total / rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_is_seed_deterministic() {
        let a = AuditSampler::new(0.25, 42);
        let b = AuditSampler::new(0.25, 42);
        let da: Vec<bool> = (0..512).map(|_| a.decide()).collect();
        let db: Vec<bool> = (0..512).map(|_| b.decide()).collect();
        assert_eq!(da, db, "same seed + stream must sample the same indices");
        let c = AuditSampler::new(0.25, 43);
        let dc: Vec<bool> = (0..512).map(|_| c.decide()).collect();
        assert_ne!(da, dc, "a different seed should pick a different subset");
        assert_eq!(a.decisions(), 512);
    }

    #[test]
    fn sampler_rate_endpoints_and_proportion() {
        let off = AuditSampler::new(0.0, 7);
        assert!((0..256).all(|_| !off.decide()));
        assert_eq!(off.decisions(), 0, "rate 0 takes no counter ticks");
        let on = AuditSampler::new(1.0, 7);
        assert!((0..256).all(|_| on.decide()));
        let half = AuditSampler::new(0.5, 7);
        let hits = (0..4096).filter(|_| half.decide()).count();
        assert!(
            (1500..=2600).contains(&hits),
            "rate 0.5 sampled {hits}/4096"
        );
    }

    #[test]
    fn offer_is_bounded_and_counts_drops() {
        let plane = AuditPlane::new(AuditConfig {
            rate: 1.0,
            queue_cap: 4,
            ..AuditConfig::default()
        });
        let mk = |i: usize| AuditSample {
            key: 0,
            rows: 1,
            dims: 2,
            input: vec![i as f32, 0.0],
            served: vec![0.0, 0.0],
        };
        for i in 0..10 {
            plane.offer(mk(i));
        }
        assert_eq!(plane.backlog(), 4, "queue stays bounded");
        assert_eq!(plane.drops.load(Ordering::Relaxed), 6);
        assert_eq!(plane.enqueued.load(Ordering::Relaxed), 10);
        // drop-oldest: the survivors are the newest four
        let q = plane.queue.lock().unwrap();
        let heads: Vec<f32> = q.iter().map(|s| s.input[0]).collect();
        assert_eq!(heads, vec![6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn relative_error_is_zero_on_match_and_scales() {
        let r = [1.0f32, 2.0, 3.0, 4.0];
        assert!(relative_error(&r, &r, 2) < 1e-12);
        let served = [1.1f32, 2.0, 3.0, 4.0];
        let e = relative_error(&served, &r, 2);
        assert!(e > 0.01 && e < 0.05, "got {e}");
    }
}
