//! Input-drift detection: streaming sketches of request state
//! distributions, compared against the training-distribution stamp.
//!
//! The residual fit (PAPER.md §3, eq. 7–8) only bounds hypersolver error on
//! the *training* state distribution; off-distribution inputs silently
//! degrade. This module gives the serving plane a cheap way to notice:
//!
//! * [`TrainStats`] — a compact stamp of the training state distribution
//!   (per-dim mean/variance + a log-magnitude histogram) that the exporters
//!   (`hypertrain`, `write_sweep_artifacts`) embed in the manifest under a
//!   task's `train_stats` field. Absent ⇒ drift reporting is disabled for
//!   that task, loudly.
//! * [`DriftSketch`] — the live side: per-dim Welford mean/variance plus the
//!   same magnitude histogram, updated per audited request row by the audit
//!   worker (off the dispatch hot path).
//! * [`DriftSketch::score`] — a scalar divergence between the two, exposed
//!   as the per-(task, variant) `hypersolvers_drift_score` gauge.

use crate::util::json::{self, Value};
use crate::{Error, Result};

/// Log₂-magnitude histogram resolution: bucket `i` covers
/// `|x| ∈ [2^(i-16), 2^(i-15))`, clamped at both ends, so the sketch spans
/// `2^-16 ..= 2^16` — comfortably beyond any sane normalized model input.
/// Zeros land in bucket 0.
pub const MAG_BUCKETS: usize = 32;

/// Bucket index for `|x|` in the magnitude histogram.
#[inline]
pub fn mag_bucket(x: f32) -> usize {
    let a = x.abs();
    if !(a.is_finite()) || a < 1.5258789e-5 {
        // below 2^-16 (or NaN/inf, which the strict loaders reject upstream)
        return 0;
    }
    let e = a.log2().floor() as i32 + 16;
    e.clamp(0, MAG_BUCKETS as i32 - 1) as usize
}

/// Training-distribution stamp: what the hypersolver's residual loss
/// actually saw. Serialized into the manifest (`train_stats`) by the
/// exporters; strict-parsed back by [`TrainStats::from_json`].
#[derive(Clone, Debug, PartialEq)]
pub struct TrainStats {
    /// number of training states summarized
    pub count: u64,
    /// per-dim mean
    pub mean: Vec<f64>,
    /// per-dim population variance
    pub var: Vec<f64>,
    /// log₂-magnitude histogram over all coordinates ([`MAG_BUCKETS`] wide)
    pub mag: Vec<u64>,
}

impl TrainStats {
    /// Summarize `rows × dims` training states (row-major), e.g. the batch
    /// the exporter sampled from the training state distribution.
    pub fn from_rows(data: &[f32], dims: usize) -> Result<TrainStats> {
        if dims == 0 || data.is_empty() || data.len() % dims != 0 {
            return Err(Error::Other(format!(
                "train_stats: need non-empty row-major data divisible by dims (len {} dims {dims})",
                data.len()
            )));
        }
        let rows = data.len() / dims;
        let mut mean = vec![0.0f64; dims];
        let mut m2 = vec![0.0f64; dims];
        let mut mag = vec![0u64; MAG_BUCKETS];
        for (r, row) in data.chunks_exact(dims).enumerate() {
            let n = (r + 1) as f64;
            for (d, &x) in row.iter().enumerate() {
                if !x.is_finite() {
                    return Err(Error::Other(format!(
                        "train_stats: non-finite state coordinate at row {r} dim {d}"
                    )));
                }
                let xf = x as f64;
                let delta = xf - mean[d];
                mean[d] += delta / n;
                m2[d] += delta * (xf - mean[d]);
                mag[mag_bucket(x)] += 1;
            }
        }
        let var = m2.iter().map(|&s| s / rows as f64).collect();
        Ok(TrainStats {
            count: rows as u64,
            mean,
            var,
            mag,
        })
    }

    /// Manifest serialization (see rust/README.md §"Numerical health" for
    /// the schema).
    pub fn to_json(&self) -> Value {
        let nums = |xs: &[f64]| Value::Arr(xs.iter().map(|&x| json::num(x)).collect());
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("mean", nums(&self.mean)),
            ("var", nums(&self.var)),
            (
                "mag",
                Value::Arr(self.mag.iter().map(|&c| json::num(c as f64)).collect()),
            ),
        ])
    }

    /// Strict parse: a *present* `train_stats` that is malformed is a hard
    /// manifest error (PR 6 convention: never silently default), while an
    /// absent one merely disables drift reporting.
    pub fn from_json(v: &Value) -> Result<TrainStats> {
        let uint = |v: &Value, what: &str| -> Result<u64> {
            let x = v
                .as_f64()
                .ok_or_else(|| Error::Manifest(format!("train_stats: {what} must be a number")))?;
            if !x.is_finite() || x < 0.0 || x.fract() != 0.0 || x > 9.007_199_254_740_992e15 {
                return Err(Error::Manifest(format!(
                    "train_stats: {what} must be a non-negative integer, got {x}"
                )));
            }
            Ok(x as u64)
        };
        let count = uint(v.req("count")?, "count")?;
        if count == 0 {
            return Err(Error::Manifest("train_stats: count must be > 0".into()));
        }
        let floats = |key: &str| -> Result<Vec<f64>> {
            let arr = v
                .req(key)?
                .as_arr()
                .ok_or_else(|| Error::Manifest(format!("train_stats: {key} must be an array")))?;
            arr.iter()
                .map(|x| {
                    x.as_f64().filter(|f| f.is_finite()).ok_or_else(|| {
                        Error::Manifest(format!("train_stats: {key} entries must be finite numbers"))
                    })
                })
                .collect()
        };
        let mean = floats("mean")?;
        let var = floats("var")?;
        if mean.is_empty() || mean.len() != var.len() {
            return Err(Error::Manifest(format!(
                "train_stats: mean/var must be same-length non-empty arrays ({} vs {})",
                mean.len(),
                var.len()
            )));
        }
        if var.iter().any(|&x| x < 0.0) {
            return Err(Error::Manifest("train_stats: var entries must be >= 0".into()));
        }
        let mag_arr = v
            .req("mag")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("train_stats: mag must be an array".into()))?;
        if mag_arr.len() != MAG_BUCKETS {
            return Err(Error::Manifest(format!(
                "train_stats: mag must have {MAG_BUCKETS} buckets, got {}",
                mag_arr.len()
            )));
        }
        let mag = mag_arr
            .iter()
            .map(|x| uint(x, "mag bucket"))
            .collect::<Result<Vec<u64>>>()?;
        Ok(TrainStats {
            count,
            mean,
            var,
            mag,
        })
    }
}

/// Live-side streaming sketch: per-dim Welford mean/variance + magnitude
/// histogram of the request states actually hitting a (task, variant)
/// queue. Single-writer (the audit worker owns it behind the key's lock);
/// reads snapshot via [`DriftSketch::score`].
#[derive(Clone, Debug, Default)]
pub struct DriftSketch {
    count: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
    mag: Vec<u64>,
}

impl DriftSketch {
    pub fn new(dims: usize) -> DriftSketch {
        DriftSketch {
            count: 0,
            mean: vec![0.0; dims],
            m2: vec![0.0; dims],
            mag: vec![0; MAG_BUCKETS],
        }
    }

    /// rows observed so far
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold one state row in (Welford update per dim + magnitude buckets).
    /// Rows whose width disagrees with the sketch are ignored — the caller
    /// (audit worker) screens dims before observing.
    pub fn observe_row(&mut self, row: &[f32]) {
        if row.len() != self.mean.len() {
            return;
        }
        self.count += 1;
        let n = self.count as f64;
        for (d, &x) in row.iter().enumerate() {
            let xf = x as f64;
            let delta = xf - self.mean[d];
            self.mean[d] += delta / n;
            self.m2[d] += delta * (xf - self.mean[d]);
            self.mag[mag_bucket(x)] += 1;
        }
    }

    /// Scalar divergence vs the training stamp: mean-shift term (per-dim
    /// |Δmean| in training-σ units) + variance-ratio term (|ln σ²-ratio|)
    /// + total-variation distance of the normalized magnitude histograms,
    /// averaged where appropriate. ≈0 in-distribution; grows without bound
    /// as the live states leave the training box. `None` until at least
    /// one row has been observed or if dims disagree with the stamp.
    pub fn score(&self, train: &TrainStats) -> Option<f64> {
        if self.count == 0 || self.mean.len() != train.mean.len() {
            return None;
        }
        const EPS: f64 = 1e-9;
        let dims = self.mean.len() as f64;
        let mut shift = 0.0;
        let mut spread = 0.0;
        for d in 0..self.mean.len() {
            let live_var = self.m2[d] / self.count as f64;
            shift += (self.mean[d] - train.mean[d]).abs() / (train.var[d] + EPS).sqrt();
            spread += ((live_var + EPS) / (train.var[d] + EPS)).ln().abs();
        }
        let live_total: u64 = self.mag.iter().sum();
        let train_total: u64 = train.mag.iter().sum();
        let mut tv = 0.0;
        if live_total > 0 && train_total > 0 {
            for b in 0..MAG_BUCKETS {
                let p = self.mag[b] as f64 / live_total as f64;
                let q = train.mag[b] as f64 / train_total as f64;
                tv += (p - q).abs();
            }
            tv *= 0.5;
        }
        Some(shift / dims + spread / dims + tv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn box_rows(n: usize, dims: usize, lo: f32, hi: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * dims)
            .map(|_| rng.uniform_in(lo as f64, hi as f64) as f32)
            .collect()
    }

    #[test]
    fn mag_buckets_cover_the_range() {
        assert_eq!(mag_bucket(0.0), 0);
        assert_eq!(mag_bucket(1e-30), 0);
        assert_eq!(mag_bucket(1.0), 16);
        assert_eq!(mag_bucket(-1.0), 16);
        assert_eq!(mag_bucket(2.5), 17);
        assert_eq!(mag_bucket(1e30), MAG_BUCKETS - 1);
    }

    #[test]
    fn from_rows_matches_direct_moments() {
        let data = [1.0f32, 10.0, 3.0, 10.0, 5.0, 10.0];
        let st = TrainStats::from_rows(&data, 2).unwrap();
        assert_eq!(st.count, 3);
        assert!((st.mean[0] - 3.0).abs() < 1e-12);
        assert!((st.mean[1] - 10.0).abs() < 1e-12);
        assert!((st.var[0] - 8.0 / 3.0).abs() < 1e-9);
        assert!(st.var[1].abs() < 1e-12);
        assert_eq!(st.mag.iter().sum::<u64>(), 6);
    }

    #[test]
    fn from_rows_rejects_garbage() {
        assert!(TrainStats::from_rows(&[], 2).is_err());
        assert!(TrainStats::from_rows(&[1.0, 2.0, 3.0], 2).is_err());
        assert!(TrainStats::from_rows(&[1.0, f32::NAN], 2).is_err());
    }

    #[test]
    fn json_round_trip_is_exact() {
        let st = TrainStats::from_rows(&box_rows(64, 3, -1.5, 1.5, 7), 3).unwrap();
        let back = TrainStats::from_json(&st.to_json()).unwrap();
        assert_eq!(st, back);
    }

    #[test]
    fn from_json_is_strict() {
        let good = TrainStats::from_rows(&box_rows(16, 2, -1.0, 1.0, 1), 2)
            .unwrap()
            .to_json();
        let break_field = |key: &str, v: Value| {
            let mut obj = good.as_obj().unwrap().clone();
            obj.insert(key.to_string(), v);
            Value::Obj(obj)
        };
        for (bad, needle) in [
            (break_field("count", json::num(0.0)), "count must be > 0"),
            (break_field("count", json::s("many")), "must be a number"),
            (break_field("mean", json::s("oops")), "must be an array"),
            (
                break_field("mean", Value::Arr(vec![json::num(f64::NAN)])),
                "finite",
            ),
            (
                break_field("var", Value::Arr(vec![json::num(1.0)])),
                "same-length",
            ),
            (
                break_field("mag", Value::Arr(vec![json::num(1.0)])),
                "buckets",
            ),
        ] {
            let err = TrainStats::from_json(&bad).unwrap_err().to_string();
            assert!(err.contains(needle), "want {needle:?} in {err:?}");
        }
        let mut missing = good.as_obj().unwrap().clone();
        missing.remove("mag");
        assert!(TrainStats::from_json(&Value::Obj(missing)).is_err());
    }

    #[test]
    fn in_distribution_scores_low_and_shift_scores_high() {
        let train = TrainStats::from_rows(&box_rows(512, 2, -1.5, 1.5, 11), 2).unwrap();
        let mut clean = DriftSketch::new(2);
        for row in box_rows(256, 2, -1.5, 1.5, 99).chunks_exact(2) {
            clean.observe_row(row);
        }
        let clean_score = clean.score(&train).unwrap();
        assert!(
            clean_score < 0.5,
            "in-distribution drift score too high: {clean_score}"
        );

        let mut shifted = DriftSketch::new(2);
        for row in box_rows(256, 2, 6.0, 12.0, 99).chunks_exact(2) {
            shifted.observe_row(row);
        }
        let shifted_score = shifted.score(&train).unwrap();
        assert!(
            shifted_score > 4.0 * clean_score && shifted_score > 1.0,
            "shifted workload should dominate: clean {clean_score} shifted {shifted_score}"
        );
    }

    #[test]
    fn score_guards_empty_and_mismatched() {
        let train = TrainStats::from_rows(&box_rows(8, 2, -1.0, 1.0, 3), 2).unwrap();
        assert!(DriftSketch::new(2).score(&train).is_none());
        let mut wrong = DriftSketch::new(3);
        wrong.observe_row(&[0.1, 0.2, 0.3]);
        assert!(wrong.score(&train).is_none());
    }
}
