//! Lock-free overwrite-oldest ring buffer for completed [`Span`]s.
//!
//! Writers claim a slot with one `fetch_add` on the global write index
//! and publish through a per-slot sequence lock, so completion-path
//! pushes never block each other and never allocate — the ring's whole
//! footprint is the fixed slot array built at construction
//! (`tests/alloc_free.rs` pins the steady state). Readers
//! (`cmd:"trace"`) copy slots out under the same sequence protocol and
//! simply skip a slot they race with: a trace snapshot is diagnostic
//! data, and dropping one in-flight span beats stalling a dispatch
//! worker.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::Span;

/// Default ring capacity — enough recent spans to cover a burst at full
/// batch fan-out while staying a few tens of KiB.
pub const DEFAULT_SPAN_CAPACITY: usize = 512;

/// One slot: a sequence word guarding a span.
///
/// Protocol: `seq == 0` never written; even ≥ 2 stable; odd mid-write.
/// A writer CASes even → odd, writes, then stores even+2; a reader loads
/// the sequence, copies, and accepts only if the sequence is unchanged
/// and even.
struct Slot {
    seq: AtomicU64,
    span: UnsafeCell<Span>,
}

// SAFETY: the span cell is only written by the thread that won the
// seq CAS (odd = exclusively owned), and readers validate the sequence
// around their copy, discarding any value raced with a writer.
unsafe impl Sync for Slot {}

/// Fixed-capacity, lock-free, overwrite-oldest span ring.
pub struct SpanRing {
    slots: Box<[Slot]>,
    /// Monotone total push count; `next % capacity` is the slot index.
    next: AtomicU64,
}

impl SpanRing {
    pub fn new(capacity: usize) -> SpanRing {
        let capacity = capacity.max(1);
        let slots: Vec<Slot> = (0..capacity)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                span: UnsafeCell::new(Span::default()),
            })
            .collect();
        SpanRing {
            slots: slots.into_boxed_slice(),
            next: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total spans ever pushed (the overwrite window is the last
    /// `capacity()` of them).
    pub fn pushed(&self) -> u64 {
        self.next.load(Ordering::Acquire)
    }

    /// Push a completed span, overwriting the oldest once full. Never
    /// blocks and never allocates; in the rare race where another writer
    /// has lapped the whole ring and still owns this exact slot, the
    /// span is dropped rather than waited for.
    pub fn push(&self, span: Span) {
        let ticket = self.next.fetch_add(1, Ordering::AcqRel);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        let seq = slot.seq.load(Ordering::Acquire);
        if seq & 1 == 1 {
            return; // a lapped writer is mid-publish on this slot
        }
        if slot
            .seq
            .compare_exchange(seq, seq + 1, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return; // lost the slot to a lapped writer
        }
        // SAFETY: the successful CAS to an odd sequence gives this thread
        // exclusive write ownership of the slot until the release below.
        unsafe {
            *slot.span.get() = span;
        }
        slot.seq.store(seq + 2, Ordering::Release);
    }

    /// Copy up to `max` of the most recent spans into `out`, newest
    /// first. `out` is caller-provided so steady-state polling reuses one
    /// buffer. Slots mid-write (or never written) are skipped.
    pub fn snapshot_into(&self, out: &mut Vec<Span>, max: usize) {
        out.clear();
        let head = self.next.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let window = head.min(cap);
        let mut idx = head;
        while idx > head - window && out.len() < max {
            idx -= 1;
            let slot = &self.slots[(idx % cap) as usize];
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 & 1 == 1 {
                continue; // never written, or a writer owns it right now
            }
            // SAFETY: the copy is validated by re-reading the sequence —
            // if a writer raced us the sequence moved and we discard.
            let span = unsafe { *slot.span.get() };
            if slot.seq.load(Ordering::Acquire) != s1 {
                continue;
            }
            out.push(span);
        }
    }
}

impl Default for SpanRing {
    fn default() -> Self {
        SpanRing::new(DEFAULT_SPAN_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64) -> Span {
        Span {
            trace,
            ..Span::default()
        }
    }

    #[test]
    fn snapshot_returns_newest_first() {
        let r = SpanRing::new(8);
        for t in 1..=5 {
            r.push(span(t));
        }
        let mut out = Vec::new();
        r.snapshot_into(&mut out, 16);
        assert_eq!(
            out.iter().map(|s| s.trace).collect::<Vec<_>>(),
            vec![5, 4, 3, 2, 1]
        );
        r.snapshot_into(&mut out, 2);
        assert_eq!(out.iter().map(|s| s.trace).collect::<Vec<_>>(), vec![5, 4]);
    }

    #[test]
    fn overwrites_oldest_when_full() {
        let r = SpanRing::new(4);
        for t in 1..=10 {
            r.push(span(t));
        }
        assert_eq!(r.pushed(), 10);
        let mut out = Vec::new();
        r.snapshot_into(&mut out, 16);
        assert_eq!(
            out.iter().map(|s| s.trace).collect::<Vec<_>>(),
            vec![10, 9, 8, 7],
            "only the last capacity() spans survive"
        );
    }

    #[test]
    fn empty_ring_snapshots_empty() {
        let r = SpanRing::new(4);
        let mut out = vec![span(99)];
        r.snapshot_into(&mut out, 16);
        assert!(out.is_empty());
    }

    #[test]
    fn concurrent_pushes_never_lose_the_ring() {
        // hammer the ring from several threads; the snapshot must stay
        // well-formed (no torn span: trace encodes its writer+seq and the
        // redundant copy in `id` must always match)
        let r = std::sync::Arc::new(SpanRing::new(32));
        let threads: Vec<_> = (0..4u64)
            .map(|w| {
                let r = std::sync::Arc::clone(&r);
                std::thread::spawn(move || {
                    for i in 0..2000u64 {
                        let t = (w << 32) | i;
                        let s = Span {
                            trace: t,
                            id: t,
                            ..Span::default()
                        };
                        r.push(s);
                    }
                })
            })
            .collect();
        let mut out = Vec::new();
        for _ in 0..200 {
            r.snapshot_into(&mut out, 32);
            for s in &out {
                assert_eq!(s.trace, s.id, "torn span escaped the seqlock");
            }
        }
        for t in threads {
            t.join().unwrap();
        }
        r.snapshot_into(&mut out, 32);
        assert_eq!(out.len(), 32, "full ring snapshots its whole window");
        for s in &out {
            assert_eq!(s.trace, s.id);
        }
    }
}
