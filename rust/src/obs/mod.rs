//! Observability: end-to-end request tracing and metrics exposition.
//!
//! The paper's whole claim is a latency budget — hypersolvers buy
//! "time-to-prediction comparable to discrete networks" — so the serving
//! stack must be able to say *where* a slow request spent its time:
//! admission, queue, padding, solver, or reply delivery. This module is
//! the substrate:
//!
//! * [`StageStamps`] — a fixed-size per-request record of monotonic
//!   timestamps, stamped by the engine at every pipeline stage
//!   (submit → admission → enqueue → pop → pad → exec → reply) plus the
//!   solver-internal counters (NFE, and accepted/rejected steps for
//!   adaptive solvers). Plain `Copy` data, no allocation, so carrying it
//!   on every [`Request`](crate::coordinator::Request) keeps the dispatch
//!   hot path allocation-free (`tests/alloc_free.rs` pins this).
//! * [`Span`] — a completed request's stamps plus its identity (trace id,
//!   request id, interned (task, variant) key). Completed spans land in a
//!   lock-free overwrite-oldest [`ring::SpanRing`] served by
//!   `cmd:"trace"`, and the slowest land in a [`SlowTable`] served by
//!   `cmd:"trace_slow"`.
//! * [`expo`] — Prometheus text-format rendering for every counter and
//!   histogram, behind `cmd:"stats"` and the `--metrics-addr` listener.
//!
//! Solver-internal counts cross the backend boundary through a
//! thread-local ([`solver_stamp`] / [`take_solver_stamp`]): the native
//! backend stamps after each solve on the worker thread, and the engine
//! reads the stamp back right after `ExecBackend::execute` returns — no
//! signature change on the `_ws` solver hot path, and no allocation.

pub mod audit;
pub mod drift;
pub mod expo;
pub mod ring;

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Number of pipeline stages a request is stamped at.
pub const STAGE_COUNT: usize = 8;

/// Pipeline stages, in pipeline order. Timestamps stamped in this order
/// are monotonically non-decreasing (all from one monotonic clock).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// `Engine::submit_with` entry (request constructed and validated).
    Submit = 0,
    /// SLO admission decision made (request was not refused).
    Admission = 1,
    /// Enqueued into its (task, variant) batcher queue.
    Enqueue = 2,
    /// Popped from the queue as part of a ready batch.
    Pop = 3,
    /// Batch input staged (padded) into the executable layout.
    Pad = 4,
    /// Backend execution started.
    ExecStart = 5,
    /// Backend execution finished.
    ExecEnd = 6,
    /// Completion written back toward the caller.
    Reply = 7,
}

impl Stage {
    /// Every stage, in pipeline order.
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::Submit,
        Stage::Admission,
        Stage::Enqueue,
        Stage::Pop,
        Stage::Pad,
        Stage::ExecStart,
        Stage::ExecEnd,
        Stage::Reply,
    ];

    /// Stable wire/exposition name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Admission => "admission",
            Stage::Enqueue => "enqueue",
            Stage::Pop => "pop",
            Stage::Pad => "pad",
            Stage::ExecStart => "exec_start",
            Stage::ExecEnd => "exec_end",
            Stage::Reply => "reply",
        }
    }
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the process-wide monotonic epoch, never 0 — a 0
/// stamp always means "stage not reached". Monotonically non-decreasing
/// across calls (one `Instant` clock).
pub fn now_us() -> u64 {
    (epoch().elapsed().as_micros() as u64).max(1)
}

/// Allocate a fresh server-generated trace id (non-zero, process-unique).
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Fixed-size per-request stage-timestamp record: one µs stamp per
/// [`Stage`] (0 = not reached) plus the solver-internal counters. `Copy`,
/// allocation-free, carried inline on every request.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStamps {
    /// µs since the process epoch, indexed by `Stage as usize`; 0 = unset.
    pub us: [u64; STAGE_COUNT],
    /// Field evaluations actually spent by the solve that served this
    /// request's batch (falls back to the variant's nominal NFE when the
    /// backend reports none).
    pub nfe: u64,
    /// Accepted adaptive steps (dopri5 variants; 0 for fixed-step).
    pub accepted: u64,
    /// Rejected adaptive steps (dopri5 variants; 0 for fixed-step).
    pub rejected: u64,
}

impl StageStamps {
    /// Stamp `stage` with the current monotonic time.
    pub fn stamp(&mut self, stage: Stage) {
        self.us[stage as usize] = now_us();
    }

    /// Stamp `stage` with a caller-provided time (one `now_us()` shared
    /// across a whole batch keeps batch-mates' stamps identical).
    pub fn set(&mut self, stage: Stage, us: u64) {
        self.us[stage as usize] = us;
    }

    /// Raw stamp for `stage` (0 = stage not reached).
    pub fn get(&self, stage: Stage) -> u64 {
        self.us[stage as usize]
    }

    /// Duration between two stamped stages in µs; 0 when either end is
    /// unset (the request never reached that stage).
    pub fn dur_us(&self, from: Stage, to: Stage) -> u64 {
        let (a, b) = (self.get(from), self.get(to));
        if a == 0 || b == 0 {
            0
        } else {
            b.saturating_sub(a)
        }
    }
}

/// A completed request span: identity + stamps. `Copy` and fixed-size so
/// ring pushes and snapshots never allocate; the (task, variant) names
/// live behind the interned `key` (see
/// [`CoordinatorMetrics::stage_key`](crate::coordinator::CoordinatorMetrics::stage_key)).
#[derive(Clone, Copy, Debug, Default)]
pub struct Span {
    /// Trace id: client-supplied via the wire `trace` field, or
    /// server-generated ([`next_trace_id`]).
    pub trace: u64,
    /// Engine request id.
    pub id: u64,
    /// Interned (task, variant) index.
    pub key: u32,
    /// Rows the request carried.
    pub rows: u32,
    /// True when the request completed with a response (false: it failed
    /// at some stage — the stamps show which).
    pub ok: bool,
    pub stamps: StageStamps,
}

impl Span {
    /// End-to-end duration (submit → reply) in µs; 0 if never replied.
    pub fn total_us(&self) -> u64 {
        self.stamps.dur_us(Stage::Submit, Stage::Reply)
    }
}

thread_local! {
    static SOLVER: Cell<(u64, u64, u64)> = const { Cell::new((0, 0, 0)) };
}

/// Record solver-internal counters (NFE, accepted, rejected) for the
/// solve that just ran on this thread. Called by the execution backend;
/// read back by the engine via [`take_solver_stamp`] right after
/// `execute` returns. Thread-local `Cell` — no locks, no allocation.
pub fn solver_stamp(nfe: u64, accepted: u64, rejected: u64) {
    SOLVER.with(|c| c.set((nfe, accepted, rejected)));
}

/// Read and clear this thread's solver stamp. Returns `(0, 0, 0)` when
/// the backend did not stamp (e.g. it executed on another thread).
pub fn take_solver_stamp() -> (u64, u64, u64) {
    SOLVER.with(|c| c.replace((0, 0, 0)))
}

/// Top-K slowest completed spans by end-to-end latency, kept
/// incrementally (`cmd:"trace_slow"`). The table is a fixed-capacity
/// vector behind a mutex — offers replace the current minimum, so
/// steady-state inserts allocate nothing.
pub struct SlowTable {
    k: usize,
    spans: Mutex<Vec<Span>>,
}

impl SlowTable {
    pub fn new(k: usize) -> SlowTable {
        let k = k.max(1);
        SlowTable {
            k,
            spans: Mutex::new(Vec::with_capacity(k)),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Span>> {
        match self.spans.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Offer a completed span; kept only while it is among the K slowest.
    pub fn offer(&self, span: Span) {
        let total = span.total_us();
        let mut g = self.lock();
        if g.len() < self.k {
            g.push(span);
            return;
        }
        let (mut mi, mut mv) = (0usize, u64::MAX);
        for (i, s) in g.iter().enumerate() {
            let t = s.total_us();
            if t < mv {
                mi = i;
                mv = t;
            }
        }
        if total > mv {
            g[mi] = span;
        }
    }

    /// Copy the current exemplars into `out`, slowest first.
    pub fn snapshot_into(&self, out: &mut Vec<Span>) {
        out.clear();
        out.extend(self.lock().iter().copied());
        out.sort_by_key(|s| std::cmp::Reverse(s.total_us()));
    }
}

impl Default for SlowTable {
    fn default() -> Self {
        SlowTable::new(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamps_are_monotone_in_stage_order() {
        let mut st = StageStamps::default();
        for s in Stage::ALL {
            st.stamp(s);
        }
        for w in Stage::ALL.windows(2) {
            assert!(
                st.get(w[0]) <= st.get(w[1]),
                "{} > {}",
                w[0].name(),
                w[1].name()
            );
            assert!(st.get(w[0]) > 0, "stamp never 0 once stamped");
        }
    }

    #[test]
    fn durations_treat_unset_stages_as_zero() {
        let mut st = StageStamps::default();
        assert_eq!(st.dur_us(Stage::Submit, Stage::Reply), 0);
        st.set(Stage::Submit, 100);
        assert_eq!(st.dur_us(Stage::Submit, Stage::Reply), 0, "reply unset");
        st.set(Stage::Reply, 350);
        assert_eq!(st.dur_us(Stage::Submit, Stage::Reply), 250);
        // a stamp pair recorded out of order saturates rather than wraps
        st.set(Stage::Pop, 400);
        st.set(Stage::Pad, 390);
        assert_eq!(st.dur_us(Stage::Pop, Stage::Pad), 0);
    }

    #[test]
    fn trace_ids_are_distinct_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn solver_stamp_is_read_once() {
        solver_stamp(12, 5, 2);
        assert_eq!(take_solver_stamp(), (12, 5, 2));
        assert_eq!(take_solver_stamp(), (0, 0, 0), "cleared after read");
    }

    #[test]
    fn slow_table_keeps_the_k_slowest() {
        let t = SlowTable::new(2);
        let mk = |trace: u64, total: u64| {
            let mut s = Span {
                trace,
                ..Span::default()
            };
            s.stamps.set(Stage::Submit, 1);
            s.stamps.set(Stage::Reply, 1 + total);
            s
        };
        t.offer(mk(1, 100));
        t.offer(mk(2, 50));
        t.offer(mk(3, 200)); // evicts the 50µs span
        t.offer(mk(4, 10)); // too fast, ignored
        let mut out = Vec::new();
        t.snapshot_into(&mut out);
        assert_eq!(
            out.iter().map(|s| s.trace).collect::<Vec<_>>(),
            vec![3, 1],
            "slowest first"
        );
    }
}
