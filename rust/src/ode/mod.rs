//! ODE abstractions and analytic test fields.
//!
//! [`VectorField`] is the interface the native solvers integrate. States are
//! batched [`Tensor`]s (leading batch dim) so one trait serves the 2-D CNF
//! states, the NCHW conv states, and the analytic fields used for solver
//! order verification.

use crate::tensor::{Tensor, Workspace};

/// A (possibly time-dependent) vector field ż = f(s, z).
pub trait VectorField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor;

    /// Write f(s, z) into `out` (same shape as `z`, fully overwritten),
    /// drawing any scratch from `ws`. The solver hot loop calls this; the
    /// default falls back to [`eval`](Self::eval) — so external impls keep
    /// compiling — and every field in this crate overrides it to run
    /// allocation-free once `ws` is warm. Overrides must produce
    /// bit-identical values to `eval` (`tests/workspace_parity.rs` checks).
    fn eval_into(&self, s: f32, z: &Tensor, out: &mut Tensor, ws: &mut Workspace) {
        let _ = ws;
        let r = self.eval(s, z);
        if r.shape() == out.shape() {
            out.copy_from(&r);
        } else {
            // misbehaving eval (wrong output shape): hand the tensor
            // through so the solver's own shape checks report Err, exactly
            // as the pre-workspace implementation did
            *out = r;
        }
    }

    /// Analytic MACs per *sample* per evaluation (0 when meaningless).
    fn macs(&self) -> u64 {
        0
    }
}

impl<F: Fn(f32, &Tensor) -> Tensor> VectorField for F {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        self(s, z)
    }
}

/// ż = λ z (exact: z0 e^{λ s}) — the classic stability/accuracy probe.
#[derive(Clone, Copy, Debug)]
pub struct Decay {
    pub lambda: f32,
}

impl VectorField for Decay {
    fn eval(&self, _s: f32, z: &Tensor) -> Tensor {
        z.scale(self.lambda)
    }

    fn eval_into(&self, _s: f32, z: &Tensor, out: &mut Tensor, _ws: &mut Workspace) {
        out.copy_from(z);
        out.map_inplace(|x| self.lambda * x);
    }
}

impl Decay {
    pub fn exact(&self, z0: &Tensor, s: f32) -> Tensor {
        z0.scale((self.lambda * s).exp())
    }
}

/// Planar rotation ż = A z with A = [[0, ω], [-ω, 0]]
/// (exact: clockwise rotation by ωs). States are (B, 2).
#[derive(Clone, Copy, Debug)]
pub struct Rotation {
    pub omega: f32,
}

impl VectorField for Rotation {
    fn eval(&self, _s: f32, z: &Tensor) -> Tensor {
        let b = z.shape()[0];
        Tensor::from_fn(&[b, 2], |i| {
            let (row, col) = (i / 2, i % 2);
            let x = z.data()[row * 2];
            let y = z.data()[row * 2 + 1];
            if col == 0 {
                self.omega * y
            } else {
                -self.omega * x
            }
        })
    }

    fn eval_into(&self, _s: f32, z: &Tensor, out: &mut Tensor, _ws: &mut Workspace) {
        assert_eq!(out.shape(), z.shape(), "eval_into shape mismatch");
        let b = z.shape()[0];
        let zd = z.data();
        let od = out.data_mut();
        for row in 0..b {
            let x = zd[row * 2];
            let y = zd[row * 2 + 1];
            od[row * 2] = self.omega * y;
            od[row * 2 + 1] = -self.omega * x;
        }
    }
}

impl Rotation {
    pub fn exact(&self, z0: &Tensor, s: f32) -> Tensor {
        let (c, si) = ((self.omega * s).cos(), (self.omega * s).sin());
        let b = z0.shape()[0];
        Tensor::from_fn(&[b, 2], |i| {
            let (row, col) = (i / 2, i % 2);
            let x = z0.data()[row * 2];
            let y = z0.data()[row * 2 + 1];
            if col == 0 {
                c * x + si * y
            } else {
                -si * x + c * y
            }
        })
    }
}

/// Van der Pol oscillator (µ controls stiffness) — the adversarial /
/// stiffness discussion of paper §B.2 needs a controllably stiff field.
#[derive(Clone, Copy, Debug)]
pub struct VanDerPol {
    pub mu: f32,
}

impl VectorField for VanDerPol {
    fn eval(&self, _s: f32, z: &Tensor) -> Tensor {
        let b = z.shape()[0];
        Tensor::from_fn(&[b, 2], |i| {
            let (row, col) = (i / 2, i % 2);
            let x = z.data()[row * 2];
            let y = z.data()[row * 2 + 1];
            if col == 0 {
                y
            } else {
                self.mu * (1.0 - x * x) * y - x
            }
        })
    }

    fn eval_into(&self, _s: f32, z: &Tensor, out: &mut Tensor, _ws: &mut Workspace) {
        assert_eq!(out.shape(), z.shape(), "eval_into shape mismatch");
        let b = z.shape()[0];
        let zd = z.data();
        let od = out.data_mut();
        for row in 0..b {
            let x = zd[row * 2];
            let y = zd[row * 2 + 1];
            od[row * 2] = y;
            od[row * 2 + 1] = self.mu * (1.0 - x * x) * y - x;
        }
    }
}

/// Time-dependent field ż = cos(2πs)·1 (exact: z0 + sin(2πs)/2π) — catches
/// solvers that mishandle stage times c_i.
#[derive(Clone, Copy, Debug)]
pub struct TimeCosine;

impl VectorField for TimeCosine {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        let v = (2.0 * std::f32::consts::PI * s).cos();
        Tensor::full(z.shape(), v)
    }

    fn eval_into(&self, s: f32, z: &Tensor, out: &mut Tensor, _ws: &mut Workspace) {
        assert_eq!(out.shape(), z.shape(), "eval_into shape mismatch");
        out.fill((2.0 * std::f32::consts::PI * s).cos());
    }
}

impl TimeCosine {
    pub fn exact(&self, z0: &Tensor, s: f32) -> Tensor {
        let two_pi = 2.0 * std::f32::consts::PI;
        let shift = (two_pi * s).sin() / two_pi;
        z0.map(|x| x + shift)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_field_and_exact() {
        let f = Decay { lambda: -2.0 };
        let z = Tensor::full(&[1, 3], 1.0);
        let dz = f.eval(0.0, &z);
        assert_eq!(dz.data(), &[-2.0, -2.0, -2.0]);
        let e = f.exact(&z, 1.0);
        assert!((e.data()[0] - (-2.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn rotation_preserves_norm() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![3.0, 4.0]).unwrap();
        let z1 = f.exact(&z0, 0.73);
        assert!((z1.frobenius_norm() - 5.0).abs() < 1e-5);
    }

    #[test]
    fn rotation_field_orthogonal_to_state() {
        let f = Rotation { omega: 2.0 };
        let z = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let dz = f.eval(0.0, &z);
        let dot = z.data()[0] * dz.data()[0] + z.data()[1] * dz.data()[1];
        assert!(dot.abs() < 1e-6);
    }

    #[test]
    fn closure_is_a_field() {
        let f = |_s: f32, z: &Tensor| z.scale(2.0);
        let z = Tensor::full(&[2, 2], 1.0);
        assert_eq!(f.eval(0.0, &z).data()[0], 2.0);
    }

    #[test]
    fn time_cosine_exact() {
        let f = TimeCosine;
        let z0 = Tensor::zeros(&[1, 1]);
        let e = f.exact(&z0, 0.25);
        assert!((e.data()[0] - 1.0 / (2.0 * std::f32::consts::PI)).abs() < 1e-6);
    }

    #[test]
    fn eval_into_overrides_match_eval() {
        let mut ws = Workspace::new();
        let z = Tensor::new(&[2, 2], vec![0.3, -1.2, 2.5, 0.7]).unwrap();
        let fields: Vec<Box<dyn VectorField>> = vec![
            Box::new(Decay { lambda: -1.7 }),
            Box::new(Rotation { omega: 2.3 }),
            Box::new(VanDerPol { mu: 4.0 }),
            Box::new(TimeCosine),
        ];
        for f in &fields {
            for s in [0.0, 0.37, 1.0] {
                let pure = f.eval(s, &z);
                let mut out = Tensor::full(&[2, 2], f32::NAN);
                f.eval_into(s, &z, &mut out, &mut ws);
                assert_eq!(out.data(), pure.data());
            }
        }
        // the closure impl exercises the default fallback
        let g = |_s: f32, z: &Tensor| z.scale(2.0);
        let mut out = Tensor::zeros(&[2, 2]);
        g.eval_into(0.0, &z, &mut out, &mut ws);
        assert_eq!(out.data(), g.eval(0.0, &z).data());
    }
}
