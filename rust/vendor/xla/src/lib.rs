//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! This environment has no XLA/PJRT runtime, so this crate provides the
//! exact API surface `runtime/exec.rs` compiles against while making the
//! unavailability explicit at runtime: [`PjRtClient::cpu`] returns an error,
//! which the executor thread surfaces at spawn time. Everything downstream
//! of a client (compilation, buffers, literals) is therefore unreachable in
//! practice; those methods return errors defensively rather than panicking.
//!
//! Swapping in the real bindings is a one-line change in the workspace
//! manifest — no source change in the main crate.

use std::fmt;

/// Error type mirroring the real crate's (stringly, `Display`-able).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }

    fn stub() -> Error {
        Error::new(
            "PJRT runtime is not available in this offline build (xla stub \
             crate) — use the native execution backend",
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a literal can carry (subset the serving layer handles).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Primitive types accepted by [`Literal::convert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Marker for element types [`Literal::to_vec`] can decode.
pub trait NativeType: Sized {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A host-side literal (stub: shape/data are never actually materialised).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::stub())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation built from a proto.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// The PJRT client. In this stub, construction always fails — callers are
/// expected to fall back to (or be configured for) the native backend.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("native execution backend"));
    }

    #[test]
    fn literal_paths_error_not_panic() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.ty().is_err());
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.convert(PrimitiveType::F32).is_err());
        assert!(l.to_tuple().is_err());
    }
}
