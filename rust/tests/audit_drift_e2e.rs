//! End-to-end numerical-health plane: shadow-audit sampling, error-budget
//! tracking and input-drift detection, driven through real engines.
//!
//! Two layers:
//!
//! * fixtures engine (synthetic native artifacts): with `rate: 1.0` every
//!   completed request is shadow-audited, and all the health read
//!   surfaces are pinned — `cmd:"health"` JSON, every new Prometheus
//!   family (validated by `expo::self_check` with the health families
//!   required, exactly as `benchgate --expo-check-health` runs it), and
//!   the strict optional `n`/`k` params on `cmd:"trace"`/`"trace_slow"`;
//! * trained engine: a small Van der Pol hypersolver is trained and
//!   exported (stamping `train_stats` into the manifest), then served.
//!   In-distribution traffic stays breach-free with a low drift score;
//!   far-off-distribution traffic trips both the drift gauge and the
//!   budget-breach counter — the failure mode the whole plane exists to
//!   catch, since the residual fit only bounds error on the training
//!   distribution.

use std::path::PathBuf;
use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use hypersolvers::coordinator::{server, Engine, EngineConfig, Policy, SubmitOptions};
use hypersolvers::nn::{AnalyticField, FieldNet};
use hypersolvers::obs::audit::AuditConfig;
use hypersolvers::obs::expo;
use hypersolvers::runtime::BackendKind;
use hypersolvers::train::{
    export_trained, hyper_variant_name, train_hypersolver, FineRef, StateSampler,
    TrainConfig,
};
use hypersolvers::util::fixtures;
use hypersolvers::util::json::Value;
use hypersolvers::util::prng::Rng;

/// The Prometheus families the audit plane adds — the same list
/// `benchgate --expo-check-health` requires of a scraped exposition.
const HEALTH_FAMILIES: [&str; 5] = [
    "hypersolvers_audit_samples_total",
    "hypersolvers_audit_drops_total",
    "hypersolvers_audit_budget_breach_total",
    "hypersolvers_audit_error",
    "hypersolvers_drift_score",
];

fn audited_engine(dir: PathBuf, rate: f64) -> Engine {
    Engine::new(EngineConfig {
        artifacts_dir: dir,
        max_wait: Duration::from_millis(1),
        policy: Policy::MinMacs,
        backend: BackendKind::Native,
        workers: 2,
        audit: AuditConfig {
            rate,
            ..AuditConfig::default()
        },
        ..Default::default()
    })
    .unwrap()
}

/// Wait (bounded) until the audit ledgers hold at least `want` samples.
/// The dedicated worker and `audit_flush` drain the queue concurrently,
/// so a single flush can return while the worker still has the last
/// sample in flight — poll the folded state instead of the queue.
fn wait_for_samples(engine: &Engine, want: u64) {
    let t0 = Instant::now();
    loop {
        engine.audit_flush();
        let plane = engine.audit().expect("audit plane enabled");
        let got: u64 = plane.snapshot().iter().map(|k| k.samples).sum();
        if got >= want {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "audit plane stuck at {got}/{want} samples (backlog {}, drops {}, unsupported {})",
            plane.backlog(),
            plane.drops.load(Relaxed),
            plane.unsupported.load(Relaxed)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn audited_fixture_engine_exposes_every_health_surface() {
    let dir = fixtures::temp_native_artifacts("audit_surface", &[("cnf_a", 4)]).unwrap();
    let engine = audited_engine(dir.clone(), 1.0);

    // budget 0.5 routes to euler_k2 (fixture mape stamp 0.25). Fixture
    // train_stats cover a ±1.5 box, so these 2-D states are
    // in-distribution.
    for i in 0..6 {
        let x = -1.2 + 0.4 * i as f32;
        let r = engine.infer("cnf_a", 0.5, vec![x, -0.4]).unwrap();
        assert_eq!(r.variant, "euler_k2");
    }
    wait_for_samples(&engine, 6);

    let plane = engine.audit().unwrap();
    assert_eq!(plane.sampler.decisions(), 6, "one sampling decision per request");
    assert_eq!(plane.drops.load(Relaxed), 0);
    assert_eq!(plane.unsupported.load(Relaxed), 0);
    let snap = plane.snapshot();
    assert_eq!(snap.len(), 1, "one audited (task, variant) key");
    let k = &snap[0];
    assert_eq!((k.task.as_str(), k.variant.as_str()), ("cnf_a", "euler_k2"));
    assert_eq!(k.samples, 6);
    assert!(
        k.err_p50.is_finite() && k.err_p50 > 0.0,
        "euler_k2 must show real measured error, got p50 {}",
        k.err_p50
    );
    assert!(k.err_p99 >= k.err_p50);
    assert!((k.budget - 0.25).abs() < 1e-9, "budget is the manifest mape");
    assert_eq!(k.breaches, 0, "euler_k2's real error sits well under 2× budget");
    // the fixture mape stamp (0.25) is a hair under euler k2's real
    // measured error on the rotation field (~0.26), so the verdict may
    // land on either side of the budget — but never in breach
    assert!(
        matches!(k.budget_status(), "ok" | "over_budget"),
        "unexpected verdict {}",
        k.budget_status()
    );
    assert!(k.has_train_stats, "fixtures stamp train_stats");
    assert_eq!(k.drift_rows, 6);
    let score = k.drift_score.expect("train_stats present ⇒ score present");
    assert!(score.is_finite() && score >= 0.0);

    // cmd:"health" — the JSON read surface over the same snapshot
    let health = server::handle_line(&engine, r#"{"cmd":"health"}"#);
    assert_eq!(health.get("ok").and_then(Value::as_bool), Some(true));
    assert_eq!(health.get("audit").and_then(Value::as_bool), Some(true));
    assert_eq!(health.get("rate").and_then(Value::as_f64), Some(1.0));
    assert_eq!(health.get("drops").and_then(Value::as_f64), Some(0.0));
    let keys = health.get("keys").and_then(Value::as_arr).unwrap();
    assert_eq!(keys.len(), 1);
    let key = &keys[0];
    assert_eq!(key.get("task").and_then(Value::as_str), Some("cnf_a"));
    assert_eq!(key.get("variant").and_then(Value::as_str), Some("euler_k2"));
    assert_eq!(key.get("samples").and_then(Value::as_f64), Some(6.0));
    assert_eq!(key.get("breaches").and_then(Value::as_f64), Some(0.0));
    assert_eq!(
        key.get("budget_status").and_then(Value::as_str),
        Some(k.budget_status()),
        "wire verdict must mirror the snapshot"
    );
    assert!(key.get("err_ewma").and_then(Value::as_f64).is_some());
    let drift = key.get("drift").expect("drift field");
    assert_eq!(
        drift.get("rows").and_then(Value::as_f64),
        Some(6.0),
        "fixtures carry train_stats, so drift must be an object, got {drift:?}"
    );
    assert!(drift.get("score").and_then(Value::as_f64).is_some());

    // Prometheus: every health family is declared with at least one
    // sample, and the whole exposition survives the strict validator
    // with the health families required — byte-for-byte what
    // `benchgate --expo-check-health` gates in CI.
    let text = engine.render_prometheus();
    for family in HEALTH_FAMILIES {
        assert!(
            text.contains(&format!("# TYPE {family} ")),
            "family {family} not declared in:\n{text}"
        );
    }
    let mut required = vec!["hypersolvers_requests_total"];
    required.extend(HEALTH_FAMILIES);
    expo::self_check(&text, &required).unwrap();
    // golden sample frames (values move with the workload; the
    // name{labels} shape must not)
    for frame in [
        "hypersolvers_audit_samples_total{task=\"cnf_a\",variant=\"euler_k2\"} 6",
        "hypersolvers_audit_drops_total{reason=\"queue\"} 0",
        "hypersolvers_audit_drops_total{reason=\"unsupported\"} 0",
        "hypersolvers_audit_budget_breach_total{task=\"cnf_a\",variant=\"euler_k2\"} 0",
        "hypersolvers_audit_error{task=\"cnf_a\",variant=\"euler_k2\",quantile=\"0.5\"}",
        "hypersolvers_audit_error{task=\"cnf_a\",variant=\"euler_k2\",quantile=\"0.99\"}",
        "hypersolvers_audit_error_count{task=\"cnf_a\",variant=\"euler_k2\"} 6",
        "hypersolvers_drift_score{task=\"cnf_a\",variant=\"euler_k2\"}",
    ] {
        assert!(text.contains(frame), "missing frame {frame:?} in:\n{text}");
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn audit_off_engine_says_so_and_renders_no_health_families() {
    let dir = fixtures::temp_native_artifacts("audit_off", &[("cnf_a", 4)]).unwrap();
    let engine = audited_engine(dir.clone(), 0.0);
    assert!(engine.audit().is_none(), "rate 0.0 must not spin up the plane");
    engine.infer("cnf_a", 0.5, vec![0.1, 0.2]).unwrap();

    let health = server::handle_line(&engine, r#"{"cmd":"health"}"#);
    assert_eq!(health.get("ok").and_then(Value::as_bool), Some(true));
    assert_eq!(health.get("audit").and_then(Value::as_bool), Some(false));
    assert!(health
        .get("reason")
        .and_then(Value::as_str)
        .unwrap()
        .contains("--audit-rate"));

    // audit-off scrape stays byte-stable against the pre-audit shape
    let text = engine.render_prometheus();
    for family in HEALTH_FAMILIES {
        assert!(
            !text.contains(family),
            "audit-off exposition must not mention {family}"
        );
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_params_are_strict_positive_integers() {
    let dir = fixtures::temp_native_artifacts("trace_strict", &[("cnf_a", 4)]).unwrap();
    let engine = audited_engine(dir.clone(), 0.0);
    for _ in 0..3 {
        engine.infer("cnf_a", 0.5, vec![0.1, 0.2]).unwrap();
    }

    // zero and non-numeric n/k are rejected with the v1 error shape —
    // previously zero silently meant "everything" and strings were
    // silently ignored
    for bad in [
        r#"{"cmd":"trace","n":0}"#,
        r#"{"cmd":"trace","n":"lots"}"#,
        r#"{"cmd":"trace","n":-3}"#,
        r#"{"cmd":"trace","n":2.5}"#,
        r#"{"cmd":"trace_slow","k":0}"#,
        r#"{"cmd":"trace_slow","k":"all"}"#,
    ] {
        let resp = server::handle_line(&engine, bad);
        assert_eq!(
            resp.get("code").and_then(Value::as_str),
            Some("bad_request"),
            "want bad_request for {bad}, got {resp:?}"
        );
    }

    // valid and omitted params still work
    let traced = server::handle_line(&engine, r#"{"cmd":"trace","n":2}"#);
    assert_eq!(traced.get("ok").and_then(Value::as_bool), Some(true));
    assert!(traced.get("spans").and_then(Value::as_arr).unwrap().len() <= 2);
    let slow = server::handle_line(&engine, r#"{"cmd":"trace_slow","k":1}"#);
    assert_eq!(slow.get("ok").and_then(Value::as_bool), Some(true));
    assert!(slow.get("spans").and_then(Value::as_arr).unwrap().len() <= 1);
    let all = server::handle_line(&engine, r#"{"cmd":"trace_slow"}"#);
    assert_eq!(all.get("ok").and_then(Value::as_bool), Some(true));

    std::fs::remove_dir_all(&dir).ok();
}

/// Train → export (with `train_stats`) → serve: in-distribution traffic is
/// clean, a distribution shift trips the drift gauge AND the error-budget
/// breach counter. This is the tentpole scenario: the trained residual is
/// only fitted on the training box, so off-box states degrade silently
/// everywhere except the audit plane.
#[test]
fn drift_injection_trips_gauge_and_budget_breach() {
    let field = FieldNet::Analytic(AnalyticField::VanDerPol { mu: 1.0 });
    let cfg = TrainConfig {
        steps: 120,
        batch: 32,
        hidden: vec![8],
        eval_every: 40,
        eval_batch: 64,
        fine: FineRef::Rk4Substeps(4),
        sampler: StateSampler::UniformBox {
            lo: -1.5,
            hi: 1.5,
            dim: 2,
        },
        seed: 3,
        ..TrainConfig::default()
    };
    let (g, report) = train_hypersolver(&field, &cfg).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "hsolve_audit_drift_e2e_{}",
        std::process::id()
    ));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    export_trained(&dir, "vdp", &field, &g, &cfg, &report, 32).unwrap();

    let engine = audited_engine(dir.clone(), 1.0);
    // pin the hypersolved variant: its budget is its *measured* manifest
    // mape, so in-box traffic sits at the budget by construction and the
    // breach machinery (EWMA > 2× budget, 4 in a row) stays quiet
    let opts = SubmitOptions {
        variant: Some(hyper_variant_name(&cfg)),
        ..Default::default()
    };
    const ROWS: usize = 32;
    const REQS: usize = 8;
    let submit_box = |lo: f64, hi: f64, seed: u64| {
        let mut rng = Rng::new(seed);
        for _ in 0..REQS {
            let input: Vec<f32> = (0..ROWS * 2)
                .map(|_| rng.uniform_in(lo, hi) as f32)
                .collect();
            let h = engine.submit_opts("vdp", 0.05, input, ROWS, &opts).unwrap();
            h.wait().unwrap();
        }
    };

    // phase 1: in-distribution (the training box) — audited error tracks
    // the manifest mape, drift stays low, no breaches
    submit_box(-1.5, 1.5, 101);
    wait_for_samples(&engine, REQS as u64);
    let plane = engine.audit().unwrap();
    let snap = plane.snapshot();
    assert_eq!(snap.len(), 1);
    let clean = &snap[0];
    assert_eq!(clean.variant, hyper_variant_name(&cfg));
    assert_eq!(clean.samples, REQS as u64);
    assert_eq!(clean.breaches, 0, "in-distribution traffic must not breach");
    assert!(clean.has_train_stats, "export_trained must stamp train_stats");
    assert_eq!(clean.drift_rows, (REQS * ROWS) as u64);
    let clean_score = clean.drift_score.expect("stamp present ⇒ score present");
    assert!(
        clean_score < 0.75,
        "in-distribution drift score too high: {clean_score}"
    );
    // the audit error (row-norm relative) and the manifest mape
    // (elementwise, python-identical) are close but not identical in-box,
    // so the verdict may sit on either side of the budget — never breach
    assert!(
        matches!(clean.budget_status(), "ok" | "over_budget"),
        "unexpected in-distribution verdict {} (ewma {:?} budget {})",
        clean.budget_status(),
        clean.ewma,
        clean.budget
    );

    // phase 2: far off the training box. euler k=8 (h = 0.125) is
    // unstable out here (|1 + hλ| > 1 for the VdP Jacobian at |x| ≈ 5)
    // and the residual net never saw these states, while the dopri5
    // reference at tol 1e-6 still converges — served error explodes
    // relative to the in-box budget
    submit_box(4.0, 6.5, 202);
    wait_for_samples(&engine, 2 * REQS as u64);
    let snap = plane.snapshot();
    let shifted = &snap[0];
    assert_eq!(shifted.samples, 2 * REQS as u64);
    let shifted_score = shifted.drift_score.unwrap();
    assert!(
        shifted_score > 1.5 && shifted_score > 4.0 * clean_score.max(0.05),
        "shift must dominate the drift score: clean {clean_score} vs shifted {shifted_score}"
    );
    assert!(
        shifted.breaches >= 1,
        "sustained off-distribution error must breach the budget \
         (ewma {:?} vs budget {}, p99 {})",
        shifted.ewma,
        shifted.budget,
        shifted.err_p99
    );
    assert_eq!(shifted.budget_status(), "breach");
    assert!(
        shifted.err_p99 > 10.0 * shifted.budget,
        "off-box served error should dwarf the manifest budget: p99 {} budget {}",
        shifted.err_p99,
        shifted.budget
    );

    // both planes agree on the wire: health reports the breach and the
    // Prometheus exposition carries the non-zero counters
    let health = server::handle_line(&engine, r#"{"cmd":"health"}"#);
    let keys = health.get("keys").and_then(Value::as_arr).unwrap();
    assert_eq!(
        keys[0].get("budget_status").and_then(Value::as_str),
        Some("breach")
    );
    let breaches = keys[0].get("breaches").and_then(Value::as_f64).unwrap();
    assert!(breaches >= 1.0);
    let text = engine.render_prometheus();
    let mut required = vec!["hypersolvers_requests_total"];
    required.extend(HEALTH_FAMILIES);
    expo::self_check(&text, &required).unwrap();
    let breach_prefix = format!(
        "hypersolvers_audit_budget_breach_total{{task=\"vdp\",variant=\"{}\"}} ",
        hyper_variant_name(&cfg)
    );
    let breach_value: f64 = text
        .lines()
        .find_map(|l| l.strip_prefix(&breach_prefix))
        .unwrap_or_else(|| panic!("no breach sample in exposition:\n{text}"))
        .trim()
        .parse()
        .unwrap();
    assert!(breach_value >= 1.0, "exposition breach counter: {breach_value}");

    std::fs::remove_dir_all(&dir).ok();
}
