//! The engine test harness that runs anywhere: full submit→batch→execute→
//! respond pipeline on the `NativeBackend`, with **no** artifacts directory
//! and **no** PJRT runtime — synthetic manifest + weights are written to a
//! temp dir by `util::fixtures`.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use hypersolvers::coordinator::{server, Engine, EngineConfig, Policy};
use hypersolvers::runtime::BackendKind;
use hypersolvers::util::fixtures;
use hypersolvers::util::json::{self, Value};

fn native_engine(tag: &str, tasks: &[(&str, usize)], workers: usize) -> Engine {
    let dir = fixtures::temp_native_artifacts(tag, tasks).unwrap();
    Engine::new(EngineConfig {
        artifacts_dir: dir,
        max_wait: Duration::from_millis(1),
        policy: Policy::MinMacs,
        backend: BackendKind::Native,
        workers,
    })
    .unwrap()
}

/// Run `f` on a helper thread and panic if it doesn't finish in `secs` —
/// guards every test that could hang on a stuck worker join.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let t = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            // finished or panicked — join to propagate any panic
            t.join().unwrap();
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: test did not finish within {secs}s (worker pool hang?)");
        }
    }
}

#[test]
fn native_engine_serves_end_to_end() {
    with_watchdog(60, || {
        let engine = native_engine("e2e", &[("cnf_a", 4)], 2);
        assert_eq!(engine.backend_name(), "native");

        // budget routing: loose → cheapest, mid → hypersolver, tight → dopri5
        let loose = engine.infer("cnf_a", 0.5, vec![0.3, -0.2]).unwrap();
        assert_eq!(loose.variant, "euler_k2");
        let mid = engine.infer("cnf_a", 0.05, vec![0.3, -0.2]).unwrap();
        assert_eq!(mid.variant, "hyperheun_k2");
        let tight = engine.infer("cnf_a", 0.000001, vec![0.3, -0.2]).unwrap();
        assert_eq!(tight.variant, "dopri5");
        // the adaptive solve reports its measured NFE through the pipeline
        assert!(tight.nfe >= 7, "dopri5 nfe {}", tight.nfe);
        for r in [&loose, &mid, &tight] {
            assert_eq!(r.output.len(), 2);
            assert!(r.output.iter().all(|x| x.is_finite()));
        }

        // a burst batches: 8 submits, batch cap 4 → fills of 4
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                engine
                    .submit("cnf_a", 0.5, vec![0.1 * i as f32, -0.5])
                    .unwrap()
            })
            .collect();
        let mut fills = Vec::new();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.output.len(), 2);
            fills.push(resp.batch_fill);
        }
        assert!(fills.iter().any(|&f| f > 1), "never batched: {fills:?}");
        assert!(engine.metrics().responses.load(Relaxed) >= 11);
    });
}

#[test]
fn native_engine_warmup_and_rejections() {
    with_watchdog(60, || {
        let engine = native_engine("reject", &[("cnf_a", 4)], 2);
        engine.warmup("cnf_a").unwrap();
        assert!(engine.warmup("no_such_task").is_err());
        assert!(engine.submit("no_such_task", 0.1, vec![0.0]).is_err());
        // wrong sample dimension
        assert!(engine.submit("cnf_a", 0.1, vec![0.0; 5]).is_err());
    });
}

#[test]
fn worker_pool_stress_8_threads_100_submits() {
    with_watchdog(120, || {
        let engine = std::sync::Arc::new(native_engine(
            "stress",
            &[("cnf_a", 8), ("cnf_b", 8)],
            4,
        ));
        assert_eq!(engine.worker_count(), 4);

        const THREADS: usize = 8;
        const PER_THREAD: usize = 100;
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let engine = std::sync::Arc::clone(&engine);
            handles.push(thread::spawn(move || {
                let budgets = [0.5f32, 0.05, 0.000001];
                let mut rxs = Vec::with_capacity(PER_THREAD);
                for i in 0..PER_THREAD {
                    let task = if (t + i) % 2 == 0 { "cnf_a" } else { "cnf_b" };
                    let budget = budgets[i % budgets.len()];
                    let input = vec![0.01 * i as f32, -0.02 * t as f32];
                    rxs.push(engine.submit(task, budget, input).unwrap());
                }
                rxs
            }));
        }

        let mut receivers = Vec::with_capacity(THREADS * PER_THREAD);
        for h in handles {
            receivers.extend(h.join().unwrap());
        }
        assert_eq!(receivers.len(), THREADS * PER_THREAD);

        // every receiver gets exactly one response with the right output dim
        let mut responses = Vec::with_capacity(receivers.len());
        for rx in &receivers {
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("response lost");
            assert_eq!(resp.output.len(), 2, "variant {}", resp.variant);
            responses.push(resp);
        }
        let m = engine.metrics();
        assert_eq!(m.requests.load(Relaxed), (THREADS * PER_THREAD) as u64);
        assert_eq!(m.responses.load(Relaxed), (THREADS * PER_THREAD) as u64);
        assert!(m.inflight_peak.load(Relaxed) >= 1);
        // the gauge decrements just after the batch's last send — allow the
        // workers a moment to step out of run_batch before checking for leaks
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while m.inflight_batches.load(Relaxed) != 0 && std::time::Instant::now() < deadline {
            thread::yield_now();
        }
        assert_eq!(m.inflight_batches.load(Relaxed), 0, "batches leaked in-flight");

        // Drop joins all workers without hanging (the watchdog is the net),
        // and after it every channel is disconnected with nothing buffered —
        // i.e. exactly one response was ever sent per request.
        drop(engine);
        for rx in &receivers {
            assert!(matches!(
                rx.try_recv(),
                Err(mpsc::TryRecvError::Disconnected)
            ));
        }
    });
}

#[test]
fn drop_idle_engine_joins_quickly() {
    with_watchdog(30, || {
        let engine = native_engine("idle_drop", &[("cnf_a", 4)], 3);
        drop(engine); // no traffic at all — workers must still wake and exit
    });
}

#[test]
fn server_protocol_over_native_backend() {
    // the TCP front end logic, exercised via handle_line (no socket needed)
    with_watchdog(60, || {
        let engine = native_engine("server", &[("cnf_a", 4)], 2);

        let tasks = server::handle_line(&engine, r#"{"cmd":"tasks"}"#);
        assert_eq!(tasks.get("ok").and_then(Value::as_bool), Some(true));

        let backend = server::handle_line(&engine, r#"{"cmd":"backend"}"#);
        assert_eq!(
            backend.get("backend").and_then(Value::as_str),
            Some("native")
        );
        assert_eq!(backend.get("workers").and_then(Value::as_usize), Some(2));

        let resp = server::handle_line(
            &engine,
            r#"{"task":"cnf_a","budget":0.5,"input":[0.5,0.5]}"#,
        );
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp:?}");
        let out = resp.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out.len(), 2);

        let metrics = server::handle_line(&engine, r#"{"cmd":"metrics"}"#);
        assert_eq!(
            metrics.get("backend").and_then(Value::as_str),
            Some("native")
        );
        let report = metrics.get("report").unwrap().as_str().unwrap().to_string();
        assert!(report.contains("requests="), "{report}");

        // malformed request → JSON error, not a panic
        let bad = server::handle_line(&engine, r#"{"task":"nope","input":[1]}"#);
        assert_eq!(bad.get("ok").and_then(Value::as_bool), Some(false));
        let _ = json::to_string(&bad);
    });
}
