//! The engine test harness that runs anywhere: full submit→batch→execute→
//! respond pipeline on the `NativeBackend`, with **no** artifacts directory
//! and **no** PJRT runtime — synthetic manifest + weights are written to a
//! temp dir by `util::fixtures`.

use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use hypersolvers::api::ErrorCode;
use hypersolvers::coordinator::{
    server, Engine, EngineConfig, Policy, Priority, RowBlock, SloConfig, SubmitOptions,
};
use hypersolvers::runtime::BackendKind;
use hypersolvers::util::fixtures;
use hypersolvers::util::json::{self, Value};

fn native_engine(tag: &str, tasks: &[(&str, usize)], workers: usize) -> Engine {
    native_engine_wait(tag, tasks, workers, Duration::from_millis(1))
}

fn native_engine_wait(
    tag: &str,
    tasks: &[(&str, usize)],
    workers: usize,
    max_wait: Duration,
) -> Engine {
    native_engine_slo(tag, tasks, workers, max_wait, SloConfig::default())
}

fn native_engine_slo(
    tag: &str,
    tasks: &[(&str, usize)],
    workers: usize,
    max_wait: Duration,
    slo: SloConfig,
) -> Engine {
    let dir = fixtures::temp_native_artifacts(tag, tasks).unwrap();
    Engine::new(EngineConfig {
        artifacts_dir: dir,
        max_wait,
        policy: Policy::MinMacs,
        backend: BackendKind::Native,
        workers,
        slo,
        ..Default::default()
    })
    .unwrap()
}

/// Run `f` on a helper thread and panic if it doesn't finish in `secs` —
/// guards every test that could hang on a stuck worker join.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let t = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            // finished or panicked — join to propagate any panic
            t.join().unwrap();
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: test did not finish within {secs}s (worker pool hang?)");
        }
    }
}

#[test]
fn native_engine_serves_end_to_end() {
    with_watchdog(60, || {
        let engine = native_engine("e2e", &[("cnf_a", 4)], 2);
        assert_eq!(engine.backend_name(), "native");

        // budget routing: loose → cheapest, mid → hypersolver, tight → dopri5
        let loose = engine.infer("cnf_a", 0.5, vec![0.3, -0.2]).unwrap();
        assert_eq!(loose.variant, "euler_k2");
        let mid = engine.infer("cnf_a", 0.05, vec![0.3, -0.2]).unwrap();
        assert_eq!(mid.variant, "hyperheun_k2");
        let tight = engine.infer("cnf_a", 0.000001, vec![0.3, -0.2]).unwrap();
        assert_eq!(tight.variant, "dopri5");
        // the adaptive solve reports its measured NFE through the pipeline
        assert!(tight.nfe >= 7, "dopri5 nfe {}", tight.nfe);
        for r in [&loose, &mid, &tight] {
            assert_eq!(r.output.len(), 2);
            assert!(r.output.iter().all(|x| x.is_finite()));
        }

        // a burst batches: 8 submits, batch cap 4 → fills of 4
        let handles: Vec<_> = (0..8)
            .map(|i| {
                engine
                    .submit("cnf_a", 0.5, vec![0.1 * i as f32, -0.5])
                    .unwrap()
            })
            .collect();
        let mut fills = Vec::new();
        for h in handles {
            let resp = h.wait().unwrap();
            assert_eq!(resp.output.len(), 2);
            fills.push(resp.batch_fill);
        }
        assert!(fills.iter().any(|&f| f > 1), "never batched: {fills:?}");
        assert!(engine.metrics().responses.load(Relaxed) >= 11);
    });
}

#[test]
fn native_engine_warmup_and_rejections() {
    with_watchdog(60, || {
        let engine = native_engine("reject", &[("cnf_a", 4)], 2);
        engine.warmup("cnf_a").unwrap();
        assert!(engine.warmup("no_such_task").is_err());
        // rejections carry stable machine-readable codes
        let e = engine.submit("no_such_task", 0.1, vec![0.0]).unwrap_err();
        assert_eq!(e.code, ErrorCode::UnknownTask);
        // wrong sample dimension
        let e = engine.submit("cnf_a", 0.1, vec![0.0; 5]).unwrap_err();
        assert_eq!(e.code, ErrorCode::ShapeMismatch);
        // zero samples / more samples than the executable batch
        let e = engine
            .submit_opts("cnf_a", 0.1, vec![], 0, &SubmitOptions::default())
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::ShapeMismatch);
        let e = engine
            .submit_opts("cnf_a", 0.1, vec![0.0; 10], 5, &SubmitOptions::default())
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::ShapeMismatch);
        // unknown pinned variant
        let e = engine
            .submit_opts(
                "cnf_a",
                0.1,
                vec![0.0, 0.0],
                1,
                &SubmitOptions {
                    variant: Some("rk9_k99".into()),
                    ..SubmitOptions::default()
                },
            )
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::UnknownVariant);
    });
}

#[test]
fn multi_sample_requests_round_trip_row_blocks() {
    with_watchdog(60, || {
        let engine = native_engine("multirow", &[("cnf_a", 4)], 2);
        // a full-batch request (4 rows) and a smaller one (2 rows), both
        // against single-sample requests for the same variant — outputs
        // must match the single-sample answers row for row
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|i| vec![0.1 * i as f32, -0.3 + 0.2 * i as f32])
            .collect();
        let singles: Vec<Vec<f32>> = rows
            .iter()
            .map(|r| engine.infer("cnf_a", 0.5, r.clone()).unwrap().output)
            .collect();

        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let resp = engine
            .submit_opts("cnf_a", 0.5, flat, 4, &SubmitOptions::default())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.output.len(), 8);
        assert_eq!(resp.batch_fill, 4);
        for (i, s) in singles.iter().enumerate() {
            assert_eq!(&resp.output[i * 2..(i + 1) * 2], s.as_slice(), "row {i}");
        }

        // 2-row request: answered, possibly padded (fill ≤ cap)
        let flat2: Vec<f32> = rows[..2].iter().flatten().copied().collect();
        let resp2 = engine
            .submit_opts("cnf_a", 0.5, flat2, 2, &SubmitOptions::default())
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp2.output.len(), 4);
        assert_eq!(&resp2.output[..2], singles[0].as_slice());
        assert_eq!(&resp2.output[2..], singles[1].as_slice());
    });
}

#[test]
fn variant_pin_and_policy_override() {
    with_watchdog(60, || {
        let engine = native_engine("pin", &[("cnf_a", 4)], 2);
        // pin: bypasses the budget policy entirely (loose budget would
        // otherwise route to euler_k2)
        let resp = engine
            .submit_opts(
                "cnf_a",
                0.5,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    variant: Some("dopri5".into()),
                    ..SubmitOptions::default()
                },
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.variant, "dopri5");
        assert!(resp.nfe >= 7);
        // per-request policy override is accepted and still satisfies the
        // budget (the fixture's nfe/macs orders agree, so just assert
        // budget satisfaction + success)
        let resp = engine
            .submit_opts(
                "cnf_a",
                0.05,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    policy: Some(Policy::MinNfe),
                    ..SubmitOptions::default()
                },
            )
            .unwrap()
            .wait()
            .unwrap();
        assert!(resp.mape <= 0.05, "{resp:?}");
    });
}

#[test]
fn deadline_fails_fast_with_structured_code() {
    with_watchdog(60, || {
        // long batching wait + batch cap 4: a lone 1-row request only
        // flushes at its own deadline, which has then already passed
        let engine = native_engine_wait(
            "deadline",
            &[("cnf_a", 4)],
            2,
            Duration::from_millis(300),
        );
        let err = engine
            .submit_opts(
                "cnf_a",
                0.5,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    deadline: Some(Duration::from_micros(1)),
                    ..SubmitOptions::default()
                },
            )
            .unwrap()
            .wait()
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExceeded, "{err}");
        assert_eq!(engine.metrics().deadline_misses.load(Relaxed), 1);
        // a generous deadline on the same queue still serves fine
        let resp = engine
            .submit_opts(
                "cnf_a",
                0.5,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    deadline: Some(Duration::from_secs(30)),
                    ..SubmitOptions::default()
                },
            )
            .unwrap()
            .wait();
        // flushes at its max_wait point (300ms), well under the 30s
        // deadline, so this completes ok
        assert!(resp.is_ok(), "{resp:?}");
        // a deadline SHORTER than max_wait but comfortably larger than the
        // dispatch margin pulls the flush early and still gets SERVED —
        // the deadline is a usable latency SLO, not a guaranteed failure
        let resp = engine
            .submit_opts(
                "cnf_a",
                0.5,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    deadline: Some(Duration::from_millis(100)),
                    ..SubmitOptions::default()
                },
            )
            .unwrap()
            .wait();
        assert!(resp.is_ok(), "100ms deadline under 300ms max_wait: {resp:?}");
    });
}

#[test]
fn worker_pool_stress_8_threads_100_submits() {
    with_watchdog(120, || {
        let engine = std::sync::Arc::new(native_engine(
            "stress",
            &[("cnf_a", 8), ("cnf_b", 8)],
            4,
        ));
        assert_eq!(engine.worker_count(), 4);

        const THREADS: usize = 8;
        const PER_THREAD: usize = 100;
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let engine = std::sync::Arc::clone(&engine);
            handles.push(thread::spawn(move || {
                let budgets = [0.5f32, 0.05, 0.000001];
                let mut subs = Vec::with_capacity(PER_THREAD);
                for i in 0..PER_THREAD {
                    let task = if (t + i) % 2 == 0 { "cnf_a" } else { "cnf_b" };
                    let budget = budgets[i % budgets.len()];
                    let input = vec![0.01 * i as f32, -0.02 * t as f32];
                    subs.push(engine.submit(task, budget, input).unwrap());
                }
                subs
            }));
        }

        let mut submissions = Vec::with_capacity(THREADS * PER_THREAD);
        for h in handles {
            submissions.extend(h.join().unwrap());
        }
        assert_eq!(submissions.len(), THREADS * PER_THREAD);

        // every handle gets exactly one completion with the right output
        // dim, tagged with its own engine id
        let mut responses = Vec::with_capacity(submissions.len());
        for handle in &submissions {
            let done = handle
                .receiver()
                .recv_timeout(Duration::from_secs(30))
                .expect("response lost");
            assert_eq!(done.id, handle.id(), "completion id mismatch");
            let resp = done.result.expect("request failed");
            assert_eq!(resp.output.len(), 2, "variant {}", resp.variant);
            responses.push(resp);
        }
        let m = engine.metrics();
        assert_eq!(m.requests.load(Relaxed), (THREADS * PER_THREAD) as u64);
        assert_eq!(m.responses.load(Relaxed), (THREADS * PER_THREAD) as u64);
        assert_eq!(m.failures.load(Relaxed), 0);
        assert!(m.inflight_peak.load(Relaxed) >= 1);
        // the gauge decrements just after the batch's last send — allow the
        // workers a moment to step out of run_batch before checking for leaks
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while m.inflight_batches.load(Relaxed) != 0 && std::time::Instant::now() < deadline {
            thread::yield_now();
        }
        assert_eq!(m.inflight_batches.load(Relaxed), 0, "batches leaked in-flight");

        // Drop joins all workers without hanging (the watchdog is the net),
        // and after it every channel is disconnected with nothing buffered —
        // i.e. exactly one completion was ever sent per request.
        drop(engine);
        for handle in &submissions {
            assert!(matches!(
                handle.receiver().try_recv(),
                Err(mpsc::TryRecvError::Disconnected)
            ));
        }
    });
}

#[test]
fn shared_completion_channel_correlates_by_id() {
    with_watchdog(60, || {
        let engine = native_engine("shared_chan", &[("cnf_a", 4)], 2);
        let (tx, rx) = mpsc::channel();
        let mut ids = Vec::new();
        for i in 0..6 {
            let id = engine
                .submit_with(
                    "cnf_a",
                    0.5,
                    RowBlock::single(vec![0.05 * i as f32, -0.4]),
                    &SubmitOptions::default(),
                    tx.clone(),
                )
                .unwrap();
            ids.push(id);
        }
        drop(tx);
        let mut seen = Vec::new();
        for done in rx {
            assert!(done.result.is_ok(), "{done:?}");
            seen.push(done.id);
        }
        seen.sort_unstable();
        ids.sort_unstable();
        assert_eq!(seen, ids, "every id completed exactly once");
    });
}

#[test]
fn drop_idle_engine_joins_quickly() {
    with_watchdog(30, || {
        let engine = native_engine("idle_drop", &[("cnf_a", 4)], 3);
        drop(engine); // no traffic at all — workers must still wake and exit
    });
}

#[test]
fn server_protocol_over_native_backend() {
    // the TCP front end logic, exercised via handle_line (no socket needed)
    with_watchdog(60, || {
        let engine = native_engine("server", &[("cnf_a", 4)], 2);

        let tasks = server::handle_line(&engine, r#"{"cmd":"tasks"}"#);
        assert_eq!(tasks.get("ok").and_then(Value::as_bool), Some(true));

        let backend = server::handle_line(&engine, r#"{"cmd":"backend"}"#);
        assert_eq!(
            backend.get("backend").and_then(Value::as_str),
            Some("native")
        );
        assert_eq!(backend.get("workers").and_then(Value::as_usize), Some(2));

        // legacy v0 line: still answered, flat output, deprecation notice
        let resp = server::handle_line(
            &engine,
            r#"{"task":"cnf_a","budget":0.5,"input":[0.5,0.5]}"#,
        );
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp:?}");
        let out = resp.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out.len(), 2);
        assert!(out[0].as_f64().is_some(), "v0 output stays flat");
        assert!(resp.get("deprecation").is_some());
        assert!(resp.get("v").is_none());

        // v1 line: versioned reply, nested output, client id echoed
        let resp = server::handle_line(
            &engine,
            r#"{"v":1,"id":42,"task":"cnf_a","budget":0.5,"input":[[0.5,0.5],[0.1,-0.2]]}"#,
        );
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp:?}");
        assert_eq!(resp.get("v").and_then(Value::as_usize), Some(1));
        assert_eq!(resp.get("id").and_then(Value::as_usize), Some(42));
        let rows = resp.get("output").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].as_arr().unwrap().len(), 2);

        let metrics = server::handle_line(&engine, r#"{"cmd":"metrics"}"#);
        assert_eq!(
            metrics.get("backend").and_then(Value::as_str),
            Some("native")
        );
        let report = metrics.get("report").unwrap().as_str().unwrap().to_string();
        assert!(report.contains("requests="), "{report}");
        // queue depths per (task, variant) are part of the metrics surface
        let queues = metrics.get("queues").unwrap().as_arr().unwrap();
        assert!(queues
            .iter()
            .all(|q| q.get("task").is_some() && q.get("rows").is_some()));

        // malformed request → structured JSON error with a stable code
        let bad = server::handle_line(&engine, r#"{"task":"nope","input":[1]}"#);
        assert_eq!(bad.get("ok").and_then(Value::as_bool), Some(false));
        assert_eq!(
            bad.get("code").and_then(Value::as_str),
            Some("unknown_task"),
            "{bad:?}"
        );
        let bad = server::handle_line(&engine, r#"{"cmd":"reboot"}"#);
        assert_eq!(bad.get("code").and_then(Value::as_str), Some("unknown_cmd"));
        let bad = server::handle_line(
            &engine,
            r#"{"v":1,"task":"cnf_a","budget":"0.05","input":[1,2]}"#,
        );
        assert_eq!(bad.get("code").and_then(Value::as_str), Some("bad_request"));
        let _ = json::to_string(&bad);
    });
}

#[test]
fn metrics_expose_queue_depths_while_queued() {
    with_watchdog(60, || {
        // max_wait 10s + cap 4: submissions sit visibly in their queue
        let engine = native_engine_wait(
            "depths",
            &[("cnf_a", 4)],
            2,
            Duration::from_secs(10),
        );
        let _h1 = engine.submit("cnf_a", 0.5, vec![0.1, 0.2]).unwrap();
        let _h2 = engine
            .submit_opts("cnf_a", 0.5, vec![0.1, 0.2, 0.3, 0.4], 2, &SubmitOptions::default())
            .unwrap();
        let depths = engine.queue_depths();
        let d = depths
            .iter()
            .find(|d| d.task == "cnf_a" && d.variant == "euler_k2")
            .expect("queue exists");
        assert_eq!(d.requests, 2);
        assert_eq!(d.rows, 3);
        // the metrics cmd carries the same numbers
        let m = server::handle_line(&engine, r#"{"cmd":"metrics"}"#);
        let queues = m.get("queues").unwrap().as_arr().unwrap();
        let q = queues
            .iter()
            .find(|q| q.get("variant").and_then(Value::as_str) == Some("euler_k2"))
            .unwrap();
        assert_eq!(q.get("rows").and_then(Value::as_usize), Some(3));
        // dropping the engine abandons the queued requests: handles see a
        // disconnect, not a hang
        drop(engine);
        assert!(_h1.wait().is_err());
    });
}

#[test]
fn admission_rejects_unmeetable_deadline_at_submit() {
    with_watchdog(60, || {
        // long batching wait + cap 4: queued rows sit until the batch fills
        let engine = native_engine_wait("admission", &[("cnf_a", 4)], 2, Duration::from_secs(10));
        let pin = |deadline: Option<Duration>| SubmitOptions {
            variant: Some("euler_k2".into()),
            deadline,
            ..SubmitOptions::default()
        };
        let queued: Vec<_> = (0..3)
            .map(|i| {
                engine
                    .submit_opts("cnf_a", 0.5, vec![0.1 * i as f32, -0.25], 1, &pin(None))
                    .unwrap()
            })
            .collect();
        // 3 rows ahead predict a wait far past a 1µs deadline → refused at
        // submit with the frozen overloaded code, before ever queueing
        let err = engine
            .submit_opts("cnf_a", 0.5, vec![0.0, 0.0], 1, &pin(Some(Duration::from_micros(1))))
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::Overloaded, "{err}");
        assert_eq!(engine.metrics().overload_rejects.load(Relaxed), 1);
        // a meetable deadline on the same queue is admitted — and fills
        // the batch, so everything queued completes
        let ok = engine
            .submit_opts("cnf_a", 0.5, vec![0.0, 0.0], 1, &pin(Some(Duration::from_secs(30))))
            .unwrap();
        assert!(ok.wait().is_ok());
        for h in queued {
            assert!(h.wait().is_ok());
        }
        // empty queue: even an absurd deadline is admitted (it fails at
        // dispatch with deadline_exceeded, not at submit)
        let err = engine
            .submit_opts("cnf_a", 0.5, vec![0.0, 0.0], 1, &pin(Some(Duration::from_micros(1))))
            .unwrap()
            .wait()
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::DeadlineExceeded, "{err}");
    });
}

#[test]
fn edf_dispatches_urgent_deadline_queue_first() {
    with_watchdog(60, || {
        // ONE worker: dispatch order is observable as completion latency.
        // A (no deadline) flushes at max_wait = 400ms; B (50ms deadline,
        // different variant queue) flushes at its deadline margin — EDF
        // must pick B long before A even though A was submitted first.
        let engine = native_engine_wait("edf", &[("cnf_a", 4)], 1, Duration::from_millis(400));
        let a = engine
            .submit_opts(
                "cnf_a",
                0.5,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    variant: Some("euler_k2".into()),
                    ..SubmitOptions::default()
                },
            )
            .unwrap();
        let b = engine
            .submit_opts(
                "cnf_a",
                0.5,
                vec![0.3, -0.2],
                1,
                &SubmitOptions {
                    variant: Some("heun_k2".into()),
                    deadline: Some(Duration::from_millis(50)),
                    ..SubmitOptions::default()
                },
            )
            .unwrap();
        let resp_b = b.wait().expect("deadlined request must be served");
        let resp_a = a.wait().expect("undeadlined request must be served");
        assert!(
            resp_b.latency < resp_a.latency,
            "EDF must serve the 50ms-deadline queue before the 400ms flush: \
             b={:?} a={:?}",
            resp_b.latency,
            resp_a.latency
        );
    });
}

#[test]
fn client_quota_rejects_submit_over_budgeted_rows() {
    with_watchdog(60, || {
        let engine = native_engine_slo(
            "quota",
            &[("cnf_a", 8)],
            2,
            Duration::from_secs(10),
            SloConfig {
                client_quota_rows: 2,
                ..SloConfig::default()
            },
        );
        let with_client = |c: Option<&str>| SubmitOptions {
            variant: Some("euler_k2".into()),
            client: c.map(str::to_string),
            ..SubmitOptions::default()
        };
        let _h1 = engine
            .submit_opts("cnf_a", 0.5, vec![0.1, 0.2], 1, &with_client(Some("c1")))
            .unwrap();
        let _h2 = engine
            .submit_opts("cnf_a", 0.5, vec![0.1, 0.2], 1, &with_client(Some("c1")))
            .unwrap();
        // c1 is at its 2-row quota: the third submit is refused…
        let err = engine
            .submit_opts("cnf_a", 0.5, vec![0.1, 0.2], 1, &with_client(Some("c1")))
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::Overloaded, "{err}");
        assert!(err.message.contains("quota"), "{err}");
        // …while other clients and unattributed requests still get in
        let _h3 = engine
            .submit_opts("cnf_a", 0.5, vec![0.1, 0.2], 1, &with_client(Some("c2")))
            .unwrap();
        let _h4 = engine.submit_opts("cnf_a", 0.5, vec![0.1, 0.2], 1, &with_client(None)).unwrap();
        assert_eq!(engine.metrics().overload_rejects.load(Relaxed), 1);
    });
}

#[test]
fn shedding_evicts_low_priority_rows_and_counts_them() {
    with_watchdog(60, || {
        // cap 8 + 10s max_wait: nothing flushes during the test. High-water
        // at 4 rows; admission off so the queue genuinely overfills.
        let engine = native_engine_slo(
            "shed",
            &[("cnf_a", 8)],
            2,
            Duration::from_secs(10),
            SloConfig {
                admission: false,
                shed_high_water_rows: 4,
                ..SloConfig::default()
            },
        );
        let prio = |p: Priority| SubmitOptions {
            variant: Some("euler_k2".into()),
            priority: p,
            ..SubmitOptions::default()
        };
        let _high: Vec<_> = (0..4)
            .map(|i| {
                engine
                    .submit_opts("cnf_a", 0.5, vec![0.1 * i as f32, 0.0], 1, &prio(Priority::High))
                    .unwrap()
            })
            .collect();
        // each low-priority submit pushes the queue past the high-water
        // mark and is immediately shed — the submit itself succeeds, the
        // completion carries the frozen overloaded code
        for _ in 0..2 {
            let h = engine
                .submit_opts("cnf_a", 0.5, vec![0.0, 0.0], 1, &prio(Priority::Low))
                .unwrap();
            let err = h.wait().unwrap_err();
            assert_eq!(err.code, ErrorCode::Overloaded, "{err}");
            assert!(err.message.contains("shed"), "{err}");
        }
        let m = engine.metrics();
        assert_eq!(m.shed.load(Relaxed), 2);
        // shed rows are failures, not deadline misses or admission rejects
        assert_eq!(m.overload_rejects.load(Relaxed), 0);
        assert_eq!(m.deadline_misses.load(Relaxed), 0);
        assert_eq!(m.failures.load(Relaxed), 2);
    });
}
