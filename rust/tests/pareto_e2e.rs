//! End-to-end Pareto pipeline: train a HyperEuler on VanDerPol, sweep the
//! grid through the `_ws` kernels and the full native serve path, and
//! assert the paper's headline claim on the produced `BENCH_pareto.json`
//! data:
//!
//! * kernel NFE-vs-error: the trained HyperEuler point strictly beats
//!   Euler AND Midpoint at the same field NFE and is a member of the
//!   NFE-vs-error Pareto front (so same-NFE Euler is dominated off it);
//! * serve-path wall-clock-vs-error: the served HyperEuler variant keeps
//!   that same-NFE error win through the full backend path, is undominated
//!   by its same-NFE rivals on the wall-clock plane, and costs less
//!   wall-clock than the tightest served dopri5 (the end-to-end speedup);
//! * the manifest `tol` axis actually drives the served adaptive solver.
//!
//! The grid pins the hypersolver at k=2 (ε = 0.5), where both same-NFE
//! rivals (euler k=2, midpoint k=1) are far off the reference — the
//! assertions hold with wide margins even for a modestly trained g.

use std::path::PathBuf;

use hypersolvers::pareto::{
    check_same_nfe_dominance, dominates, pareto_doc, run_pipeline,
    serve_speedup_vs_tightest_dopri5, GridConfig, TaskSpec,
};
use hypersolvers::util::json;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "hsolve_pareto_e2e_{tag}_{}",
        std::process::id()
    ));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn trained_hypereuler_dominates_same_nfe_rivals_on_both_planes() {
    let grid = GridConfig {
        solvers: vec!["euler".into(), "midpoint".into()],
        ks: vec![1, 2, 4],
        tols: vec![1e-3, 1e-5],
        hyper_base: "euler".into(),
        hyper_k: 2,
        batch: 64,
        seed: 11,
        span: (0.0, 1.0),
        sample_box: 2.0,
        traj_mesh_k: 8,
        traj_checkpoints: 2,
        ref_tol: 1e-7,
        measure_ms: 30,
        train_steps: 2500,
        train_hidden: vec![8],
        train_stop_at: 5.0,
        log: false,
    };
    let tasks = vec![TaskSpec::analytic("vdp").unwrap()];
    let dir = temp_dir("vdp");
    let reports = run_pipeline(&grid, &tasks, &dir).unwrap();
    assert_eq!(reports.len(), 1);
    let r = &reports[0];
    assert!(r.train.improvement > 1.0, "training helped at all: {:?}", r.train);

    // ---- kernel plane, trajectory states (the trained distribution) ----
    let chk = check_same_nfe_dominance(&r.kernel_traj, &grid).unwrap();
    assert!(
        chk.dominates_same_nfe_euler(),
        "kernel: {} err {:.3e} vs same-NFE euler {:?}",
        chk.hyper_label,
        chk.err_hyper,
        chk.err_euler
    );
    assert!(
        chk.dominates_same_nfe_midpoint(),
        "kernel: {} err {:.3e} vs same-NFE midpoint {:?}",
        chk.hyper_label,
        chk.err_hyper,
        chk.err_midpoint
    );
    assert!(chk.on_nfe_front, "kernel: {} off the NFE front", chk.hyper_label);
    // the box-states plane agrees on the euler comparison (the trained
    // correction generalizes off its training distribution)
    let boxchk = check_same_nfe_dominance(&r.kernel_box, &grid).unwrap();
    assert!(boxchk.dominates_same_nfe_euler(), "box plane: {boxchk:?}");

    // ---- serve plane: the full backend path ----
    let schk = check_same_nfe_dominance(&r.serve, &grid).unwrap();
    assert!(schk.dominates_same_nfe_euler(), "serve: {schk:?}");
    assert!(schk.dominates_same_nfe_midpoint(), "serve: {schk:?}");
    let hyper = r.serve.iter().find(|p| p.label == "hypereuler_k2").unwrap();
    let euler = r.serve.iter().find(|p| p.label == "euler_k2").unwrap();
    let midpoint = r.serve.iter().find(|p| p.label == "midpoint_k1").unwrap();
    // wall-clock plane: neither same-NFE rival dominates the hyper point
    // (they are strictly less accurate, so dominance would need them to
    // be at least as accurate — pin it explicitly)
    assert!(!dominates((euler.wall_us, euler.err), (hyper.wall_us, hyper.err)));
    assert!(!dominates((midpoint.wall_us, midpoint.err), (hyper.wall_us, hyper.err)));
    // end-to-end speedup vs the tightest served dopri5
    let sp = serve_speedup_vs_tightest_dopri5(&r.serve, &grid).unwrap();
    assert!(sp > 1.0, "served hyper slower than tight dopri5: {sp:.2}×");
    // the manifest tol axis drives the served adaptive solver
    let d5_loose = r.serve.iter().find(|p| p.label == "dopri5_1e-3").unwrap();
    let d5_tight = r.serve.iter().find(|p| p.label == "dopri5_1e-5").unwrap();
    assert!(
        d5_tight.nfe > d5_loose.nfe,
        "served dopri5 NFE ignored the manifest tol: {} vs {}",
        d5_tight.nfe,
        d5_loose.nfe
    );
    assert!(d5_tight.err <= d5_loose.err * 1.5, "tight tol should not be less accurate");

    // ---- the document round-trips with the fronts in place ----
    let doc = pareto_doc(&grid, &reports);
    let path = dir.join("BENCH_pareto.json");
    std::fs::write(&path, json::to_string(&doc)).unwrap();
    let back = json::parse_file(&path).unwrap();
    assert_eq!(back.get("bench").unwrap().as_str(), Some("hyperbench_pareto"));
    assert_eq!(back.get("schema").unwrap().as_str(), Some("bench.v1"));
    let task = &back.get("tasks").unwrap().as_arr().unwrap()[0];
    let front: Vec<&str> = task
        .get("kernel_trajectory")
        .unwrap()
        .get("front_nfe")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap())
        .collect();
    assert!(
        front.contains(&"hypereuler_k2"),
        "front_nfe in the JSON misses the hyper point: {front:?}"
    );
    assert!(
        !front.contains(&"euler_k2"),
        "same-NFE euler should be dominated off the front: {front:?}"
    );
    // the exported artifacts stay natively servable
    assert!(dir.join("manifest.json").exists());
    assert!(dir.join("weights/vdp.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}
