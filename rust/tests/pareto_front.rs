//! Property tests for the Pareto subsystem's correctness-critical pieces:
//! the extracted front must be *exactly* the non-dominated set (checked
//! against a brute-force O(n²) reference, with deliberate ties), the
//! trajectory state sampler must be seeded-deterministic, and one sweep
//! grid cell must agree with a direct solver call bit-for-bit.

use hypersolvers::ode::{Rotation, VanDerPol};
use hypersolvers::pareto::{
    dominates, kernel_sweep, method_label, non_dominated, GridConfig,
};
use hypersolvers::solvers::{adaptive, AdaptiveOpts, Tableau};
use hypersolvers::tensor::Tensor;
use hypersolvers::train::StateSampler;
use hypersolvers::util::propkit::{check, gen_vec, prop_assert};
use hypersolvers::util::prng::Rng;

/// Brute-force non-dominated set: keep i iff no j dominates it.
fn brute_force_front(pts: &[(f64, f64)]) -> Vec<usize> {
    let mut kept: Vec<usize> = (0..pts.len())
        .filter(|&i| {
            pts[i].0.is_finite()
                && pts[i].1.is_finite()
                && !pts.iter().enumerate().any(|(j, &q)| {
                    j != i && q.0.is_finite() && q.1.is_finite() && dominates(q, pts[i])
                })
        })
        .collect();
    kept.sort_by(|&a, &b| {
        pts[a]
            .0
            .partial_cmp(&pts[b].0)
            .unwrap()
            .then(pts[a].1.partial_cmp(&pts[b].1).unwrap())
            .then(a.cmp(&b))
    });
    kept
}

#[test]
fn front_is_exactly_the_non_dominated_set() {
    check("front == brute-force non-dominated set", 120, |rng| {
        let n = 3 + (rng.below(30) as usize);
        // quantize to a coarse lattice so equal-cost / equal-error /
        // fully-duplicate ties occur often
        let xs = gen_vec(rng, n, 1.0);
        let ys = gen_vec(rng, n, 1.0);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|i| {
                (
                    (xs[i].abs() * 4.0).round() as f64 / 4.0,
                    (ys[i].abs() * 4.0).round() as f64 / 4.0,
                )
            })
            .collect();
        let fast = non_dominated(&pts);
        let brute = brute_force_front(&pts);
        if fast != brute {
            return Err(format!("scan {fast:?} != brute {brute:?} on {pts:?}"));
        }
        // stable order: (cost, err, idx) ascending
        for w in fast.windows(2) {
            let (a, b) = (pts[w[0]], pts[w[1]]);
            let ord = a
                .0
                .partial_cmp(&b.0)
                .unwrap()
                .then(a.1.partial_cmp(&b.1).unwrap())
                .then(w[0].cmp(&w[1]));
            if ord != std::cmp::Ordering::Less {
                return Err(format!("unstable order {:?} then {:?}", w[0], w[1]));
            }
        }
        prop_assert(!fast.is_empty() || pts.is_empty(), "empty front")
    });
}

#[test]
fn front_never_keeps_dominated_never_drops_undominated() {
    check("membership invariants", 80, |rng| {
        let n = 2 + (rng.below(20) as usize);
        let xs = gen_vec(rng, n, 2.0);
        let ys = gen_vec(rng, n, 2.0);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|i| (xs[i].abs() as f64, ys[i].abs() as f64))
            .collect();
        let front = non_dominated(&pts);
        for &i in &front {
            for (j, &q) in pts.iter().enumerate() {
                if j != i && dominates(q, pts[i]) {
                    return Err(format!("kept {i} dominated by {j}"));
                }
            }
        }
        for i in 0..n {
            if !front.contains(&i)
                && !pts
                    .iter()
                    .enumerate()
                    .any(|(j, &q)| j != i && dominates(q, pts[i]))
            {
                return Err(format!("dropped undominated {i}"));
            }
        }
        prop_assert(true, "ok")
    });
}

#[test]
fn trajectory_sampler_is_seed_deterministic() {
    let f = VanDerPol { mu: 1.0 };
    let sampler = StateSampler::Trajectory {
        lo: -2.0,
        hi: 2.0,
        dim: 2,
        solver: "euler".into(),
        k: 8,
        span: (0.0, 1.0),
    };
    check("same seed → same draw", 20, |rng| {
        let seed = rng.next_u64();
        let a = sampler.sample_for(&f, 32, &mut Rng::new(seed)).unwrap();
        let b = sampler.sample_for(&f, 32, &mut Rng::new(seed)).unwrap();
        prop_assert(a.data() == b.data(), "seeded draws diverged")
    });
    // consuming the stream advances it — consecutive draws differ
    let mut rng = Rng::new(5);
    let a = sampler.sample_for(&f, 32, &mut rng).unwrap();
    let b = sampler.sample_for(&f, 32, &mut rng).unwrap();
    assert_ne!(a.data(), b.data());
}

#[test]
fn sweep_cell_matches_direct_solver_call() {
    // one grid cell (euler, k=4) must agree with computing the same
    // number directly: same reference construction, same solver call,
    // same metric — bit-for-bit, since both run identical code paths
    let f = Rotation { omega: 1.0 };
    let grid = GridConfig {
        solvers: vec!["euler".into()],
        ks: vec![4],
        tols: vec![],
        hyper_k: 4,
        batch: 8,
        traj_checkpoints: 4,
        measure_ms: 10,
        ..GridConfig::smoke()
    };
    let zero_g = |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| Tensor::zeros(z.shape());
    let mut rng = Rng::new(3);
    let z0 = grid.box_sampler(2).sample_for(&f, grid.batch, &mut rng).unwrap();
    let points = kernel_sweep("rot", &f, &zero_g, &grid, &z0, "box").unwrap();

    let cell = points
        .iter()
        .find(|p| p.label == method_label("euler", 4, false, None))
        .expect("euler_k4 swept");
    assert_eq!(cell.nfe, 4.0);
    assert!(cell.err_traj.is_some(), "k=4 mesh contains the 4 checkpoints");

    // reference exactly as the sweep builds it: segment-to-segment tight
    // dopri5 at the checkpoint times
    let c = grid.traj_checkpoints;
    let mut cur = z0.clone();
    for j in 1..=c {
        let t0 = (j - 1) as f32 / c as f32;
        let t1 = j as f32 / c as f32;
        cur = adaptive(
            &f,
            &cur,
            (t0, t1),
            &Tableau::dopri5(),
            &AdaptiveOpts::with_tol(grid.ref_tol),
        )
        .unwrap()
        .z;
    }
    let direct = hypersolvers::solvers::odeint_fixed(&f, &z0, (0.0, 1.0), 4, &Tableau::euler())
        .unwrap();
    let want_err = hypersolvers::metrics::mean_l2(&direct, &cur).unwrap();
    assert!(
        (cell.err - want_err).abs() <= 1e-12,
        "sweep err {} vs direct {}",
        cell.err,
        want_err
    );
    assert!(cell.wall_us > 0.0);

    // the zero-correction hypersolver point equals its base solver
    let hyper = points
        .iter()
        .find(|p| p.label == method_label("euler", 4, true, None))
        .expect("hypereuler_k4 swept");
    assert!((hyper.err - cell.err).abs() <= 1e-9);
    assert_eq!(hyper.g_evals, 4);
}
