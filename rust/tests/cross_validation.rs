//! Cross-language validation: for EVERY task and EVERY fixed-step variant in
//! the manifest, the native rust solve (weights JSON + rust solvers) must
//! reproduce the python-side measured MAPE. One assertion per exported
//! variant — ~60 parameterized checks over the whole artifact set.
//!
//! This is the strongest end-to-end invariant in the repo: it ties together
//! the JAX solvers, the AOT weight export, the rust JSON/tensor/nn stack and
//! the rust solvers in a single number per variant.

use hypersolvers::data::blobs;
use hypersolvers::metrics::mape;
use hypersolvers::nn::{CnfModel, ImageModel, TrackingModel};
use hypersolvers::ode::VectorField;
use hypersolvers::runtime::{Manifest, TaskEntry};
use hypersolvers::solvers::{
    dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, HyperNet, Tableau,
};
use hypersolvers::tensor::Tensor;

fn manifest() -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) if m.quick => {
            eprintln!("SKIP: quick artifacts");
            None
        }
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn blob(m: &Manifest, task: &TaskEntry, key: &str) -> Tensor {
    let b = &task.data[key];
    blobs::load_f32(&m.blob_path(b), &b.shape).unwrap()
}

/// Tolerance: native f32 vs XLA f32 accumulate differently; the MAPE itself
/// is an average so agreement is tight but not exact.
const TOL: f64 = 5e-3;

fn check_task(
    m: &Manifest,
    task: &TaskEntry,
    field: &dyn VectorField,
    hyper: &dyn HyperNet,
    hyper_base: &Tableau,
) -> (usize, Vec<String>) {
    let z0 = blob(m, task, "z0");
    let truth = blob(m, task, "truth");
    let mut checked = 0;
    let mut failures = Vec::new();
    for v in &task.variants {
        let zt = if v.solver == "dopri5" {
            // match the tightest export tolerance (cnf/tracking use 1e-5)
            dopri5(field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-5))
                .map(|r| r.z)
        } else if v.hyper {
            odeint_hyper(field, hyper, &z0, task.s_span, v.k, hyper_base)
        } else {
            let tab = Tableau::by_name(&v.solver).unwrap();
            odeint_fixed(field, &z0, task.s_span, v.k, &tab)
        };
        let zt = match zt {
            Ok(z) => z,
            Err(e) => {
                failures.push(format!("{}/{}: solve failed: {e}", task.name, v.name));
                continue;
            }
        };
        let measured = mape(&zt, &truth).unwrap();
        // dopri5 takes its own step sequence: only require "both tiny"
        let ok = if v.solver == "dopri5" {
            measured < 1e-2 && v.mape < 1e-2
        } else {
            (measured - v.mape).abs() < TOL
        };
        if !ok {
            failures.push(format!(
                "{}/{}: rust {measured:.5} vs python {:.5}",
                task.name, v.name, v.mape
            ));
        }
        checked += 1;
    }
    (checked, failures)
}

#[test]
fn every_variant_matches_python_mape() {
    let Some(m) = manifest() else { return };
    let mut total = 0;
    let mut all_failures = Vec::new();

    for (name, task) in &m.tasks {
        let (checked, failures) = match task.kind.as_str() {
            "cnf" => {
                let model = CnfModel::load(&m.weights_path(task)).unwrap();
                check_task(&m, task, &model.field, &model.hyper, &Tableau::heun())
            }
            "tracking" => {
                let model = TrackingModel::load(&m.weights_path(task)).unwrap();
                check_task(&m, task, &model.field, &model.hyper, &Tableau::euler())
            }
            "image" => {
                let model = ImageModel::load(&m.weights_path(task)).unwrap();
                check_task(&m, task, &model.field, &model.hyper, &Tableau::euler())
            }
            other => panic!("unknown kind {other} for {name}"),
        };
        total += checked;
        all_failures.extend(failures);
    }
    eprintln!("cross-validated {total} variants across {} tasks", m.tasks.len());
    assert!(total >= 50, "expected a full variant grid, got {total}");
    assert!(
        all_failures.is_empty(),
        "{} mismatches:\n{}",
        all_failures.len(),
        all_failures.join("\n")
    );
}

#[test]
fn hypersolver_dominates_base_at_low_nfe_everywhere() {
    // The paper's headline, asserted across every task artifact: at the
    // lowest exported NFE, the hypersolved variant beats its base solver.
    let Some(m) = manifest() else { return };
    for (name, task) in &m.tasks {
        let base_name = &task.hyper_base;
        let hypers: Vec<_> = task.variants.iter().filter(|v| v.hyper).collect();
        let min_k = hypers.iter().map(|v| v.k).min().unwrap();
        let hyper = hypers.iter().find(|v| v.k == min_k).unwrap();
        let base = task
            .variants
            .iter()
            .find(|v| !v.hyper && v.solver == *base_name && v.k == min_k)
            .unwrap_or_else(|| panic!("{name}: no base variant at k={min_k}"));
        assert!(
            hyper.mape < base.mape,
            "{name}: hyper {:.4} !< base {:.4} at K={min_k}",
            hyper.mape,
            base.mape
        );
    }
}
