//! Extra cross-module property tests (no artifacts required): solver
//! invariants on analytic fields, JSON round-trip fuzzing, workload/stats
//! properties — the "failure injection / edge case" layer on top of the
//! per-module unit tests.

use hypersolvers::data::workload::WorkloadSpec;
use hypersolvers::metrics::{mape, mean_l2};
use hypersolvers::ode::{Decay, Rotation, VectorField};
use hypersolvers::solvers::{
    adaptive, dopri5, odeint_fixed, odeint_fixed_traj, AdaptiveOpts, Tableau,
};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::json::{self, Value};
use hypersolvers::util::prng::Rng;
use hypersolvers::util::propkit::{check, gen_range, gen_vec, prop_assert};
use hypersolvers::util::stats;

// ---------------------------------------------------------------------------
// Solver invariants
// ---------------------------------------------------------------------------

#[test]
fn rotation_norm_preserved_by_high_order_solvers() {
    // ‖z(s)‖ is conserved by the rotation flow; rk4 at fine steps must
    // track it to f32 precision for random initial conditions
    check("rk4 preserves rotation norm", 25, |rng| {
        let z0 = Tensor::new(&[1, 2], gen_vec(rng, 2, 2.0)).unwrap();
        let f = Rotation { omega: 1.5 };
        let z1 = odeint_fixed(&f, &z0, (0.0, 1.0), 32, &Tableau::rk4()).unwrap();
        let drift = (z1.frobenius_norm() - z0.frobenius_norm()).abs();
        prop_assert(
            drift < 1e-4 * (1.0 + z0.frobenius_norm()),
            format!("norm drift {drift}"),
        )
    });
}

#[test]
fn step_doubling_halves_euler_error() {
    check("euler error ~ 1/K", 20, |rng| {
        let z0 = Tensor::new(&[1, 2], gen_vec(rng, 2, 1.0)).unwrap();
        let f = Rotation { omega: 1.0 };
        let exact = f.exact(&z0, 1.0);
        let e = |k: usize| {
            odeint_fixed(&f, &z0, (0.0, 1.0), k, &Tableau::euler())
                .unwrap()
                .sub(&exact)
                .unwrap()
                .frobenius_norm()
        };
        let (e16, e32) = (e(16), e(32));
        if e32 < 1e-6 {
            return Ok(()); // precision floor
        }
        let ratio = e16 / e32;
        prop_assert(
            ratio > 1.6 && ratio < 2.6,
            format!("ratio {ratio} (e16={e16}, e32={e32})"),
        )
    });
}

#[test]
fn adaptive_solvers_agree_across_pairs() {
    // dopri5 and bs32 at tight tolerance must land on the same answer
    check("dopri5 == bs32 at tol", 10, |rng| {
        let z0 = Tensor::new(&[2, 2], gen_vec(rng, 4, 1.0)).unwrap();
        let f = Rotation { omega: 2.0 };
        let a = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-6))
            .unwrap();
        let b = adaptive(
            &f,
            &z0,
            (0.0, 1.0),
            &Tableau::bs32(),
            &AdaptiveOpts::with_tol(1e-6),
        )
        .unwrap();
        let d = mean_l2(&a.z, &b.z).unwrap();
        prop_assert(d < 1e-4, format!("disagreement {d}"))
    });
}

#[test]
fn trajectory_is_flow_composition() {
    // z(s2) computed in one go equals continuing from z(s1) — group
    // property of the numerical flow at matched meshes
    check("flow composition", 20, |rng| {
        let z0 = Tensor::new(&[1, 2], gen_vec(rng, 2, 1.0)).unwrap();
        let f = Rotation { omega: 1.0 };
        let tab = Tableau::heun();
        let whole = odeint_fixed(&f, &z0, (0.0, 1.0), 8, &tab).unwrap();
        let half = odeint_fixed(&f, &z0, (0.0, 0.5), 4, &tab).unwrap();
        let rest = odeint_fixed(&f, &half, (0.5, 1.0), 4, &tab).unwrap();
        let d = whole.sub(&rest).unwrap().frobenius_norm();
        prop_assert(d < 1e-5, format!("composition gap {d}"))
    });
}

#[test]
fn trajectory_points_match_restarts() {
    let f = Decay { lambda: -1.0 };
    let z0 = Tensor::full(&[3, 2], 1.0);
    let traj = odeint_fixed_traj(&f, &z0, (0.0, 1.0), 5, &Tableau::rk4()).unwrap();
    for (i, z) in traj.iter().enumerate() {
        let direct = if i == 0 {
            z0.clone()
        } else {
            odeint_fixed(&f, &z0, (0.0, i as f32 / 5.0), i, &Tableau::rk4()).unwrap()
        };
        assert!(z.sub(&direct).unwrap().frobenius_norm() < 1e-5, "point {i}");
    }
}

#[test]
fn mape_is_scale_aware() {
    check("mape grows with perturbation", 20, |rng| {
        let n = gen_range(rng, 1, 16);
        let t = Tensor::new(&[1, n], gen_vec(rng, n, 1.0)).unwrap();
        let small = t.map(|x| x + 0.01);
        let big = t.map(|x| x + 0.5);
        let m_small = mape(&small, &t).unwrap();
        let m_big = mape(&big, &t).unwrap();
        prop_assert(m_small < m_big, format!("{m_small} !< {m_big}"))
    });
}

// ---------------------------------------------------------------------------
// JSON fuzz round-trip
// ---------------------------------------------------------------------------

fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.below(2) == 0),
        2 => Value::Num((rng.normal() * 1e3).round() / 8.0),
        3 => {
            let n = rng.below(8);
            Value::Str(
                (0..n)
                    .map(|_| {
                        let c = rng.below(96) as u8 + 32;
                        c as char
                    })
                    .collect(),
            )
        }
        4 => Value::Arr(
            (0..rng.below(4)).map(|_| gen_value(rng, depth - 1)).collect(),
        ),
        _ => Value::Obj(
            (0..rng.below(4))
                .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn json_fuzz_roundtrip() {
    check("parse(to_string(v)) == v", 200, |rng| {
        let v = gen_value(rng, 3);
        let text = json::to_string(&v);
        let back = json::parse(&text)
            .map_err(|e| format!("reparse failed on {text:?}: {e}"))?;
        prop_assert(back == v, format!("mismatch for {text}"))
    });
}

#[test]
fn json_rejects_mutations() {
    // randomly truncating valid JSON must never panic (errors are fine)
    check("no panic on truncation", 100, |rng| {
        let v = gen_value(rng, 3);
        let text = json::to_string(&v);
        if text.len() > 1 {
            let cut = 1 + rng.below(text.len() as u64 - 1) as usize;
            let cut = (0..=cut).rev().find(|&c| text.is_char_boundary(c)).unwrap();
            let _ = json::parse(&text[..cut]); // must not panic
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Workload & stats
// ---------------------------------------------------------------------------

#[test]
fn workload_rate_scaling() {
    check("duration ~ count/rate", 10, |rng| {
        let rate = 10.0 + rng.uniform() * 1000.0;
        let spec = WorkloadSpec {
            rate,
            count: 2000,
            tasks: vec!["t".into()],
            budgets: vec![(0.1, 1.0)],
        };
        let mut local = rng.fold_in(1);
        let trace = spec.generate(&mut local);
        let expected = 2000.0 / rate;
        let actual = trace.duration_s();
        prop_assert(
            (actual - expected).abs() < 0.2 * expected,
            format!("rate {rate}: duration {actual} vs {expected}"),
        )
    });
}

#[test]
fn percentile_monotone_property() {
    check("percentile monotone in q", 30, |rng| {
        let n = gen_range(rng, 2, 100);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (p10, p50, p90) = (
            stats::percentile(&xs, 10.0),
            stats::percentile(&xs, 50.0),
            stats::percentile(&xs, 90.0),
        );
        prop_assert(p10 <= p50 && p50 <= p90, format!("{p10} {p50} {p90}"))
    });
}

#[test]
fn field_macs_reported_consistently() {
    // VectorField::macs default is 0; analytic fields keep that; the trait
    // object path must not panic
    let f: &dyn VectorField = &Rotation { omega: 1.0 };
    assert_eq!(f.macs(), 0);
}
