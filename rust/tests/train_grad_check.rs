//! Finite-difference verification of every backward kernel in `train::grad`.
//!
//! Each analytic gradient is checked against central differences
//! (L(θ+h) − L(θ−h)) / 2h on random shapes to 1e-3 relative (propkit's
//! close-compare uses max(1, |a|, |b|) as the denominator, so the bound is
//! absolute for sub-unit gradients and relative above). Smooth activations
//! (tanh / softplus / id) are sampled randomly; the kinked ones (relu,
//! prelu) get cases constructed so no perturbation crosses the kink —
//! central differences are meaningless at the kink itself.

use hypersolvers::nn::{Act, Linear, Mlp, PRelu, TimeMode};
use hypersolvers::tensor::{Tensor, Workspace};
use hypersolvers::train::{
    field_input_backward, field_input_into, hyper_input_backward, hyper_input_into,
    mlp_backward, mlp_forward_cached, mse_loss, mse_loss_grad, prelu_backward, MlpCache,
    MlpGrads,
};
use hypersolvers::util::prng::Rng;
use hypersolvers::util::propkit::{check, gen_range, gen_vec, prop_assert_close};

const FD_H: f32 = 1e-2;
const TOL: f32 = 1e-3;

fn random_linear(rng: &mut Rng, din: usize, dout: usize, act: Act) -> Linear {
    Linear {
        w: Tensor::new(&[din, dout], gen_vec(rng, din * dout, 0.5)).unwrap(),
        b: gen_vec(rng, dout, 0.2),
        act,
    }
}

/// Random MLP over smooth activations (the kinked relu path gets its own
/// constructed case below).
fn random_smooth_mlp(rng: &mut Rng) -> Mlp {
    let n_layers = gen_range(rng, 1, 3);
    let mut dims = Vec::with_capacity(n_layers + 1);
    for _ in 0..=n_layers {
        dims.push(gen_range(rng, 1, 4));
    }
    let acts = [Act::Tanh, Act::Softplus, Act::Id];
    let layers = (0..n_layers)
        .map(|i| {
            let act = if i == n_layers - 1 {
                Act::Id
            } else {
                *rng.choose(&acts)
            };
            random_linear(rng, dims[i], dims[i + 1], act)
        })
        .collect();
    Mlp { layers }
}

fn loss_of(mlp: &Mlp, x: &Tensor, t: &Tensor) -> f32 {
    mse_loss(&mlp.forward(x).unwrap(), t).unwrap()
}

/// Analytic parameter + input gradients of mse(mlp(x), t).
fn analytic_grads(mlp: &Mlp, x: &Tensor, t: &Tensor) -> (Vec<f32>, Tensor) {
    let mut cache = MlpCache::new();
    mlp_forward_cached(mlp, x, &mut cache).unwrap();
    let mut dy = Tensor::zeros(t.shape());
    mse_loss_grad(cache.output(), t, &mut dy).unwrap();
    let mut grads = MlpGrads::new();
    let mut ws = Workspace::new();
    let mut dx = Tensor::zeros(x.shape());
    mlp_backward(mlp, &cache, &dy, &mut grads, Some(&mut dx), &mut ws).unwrap();
    let mut flat = Vec::new();
    grads.write_flat(&mut flat);
    (flat, dx)
}

/// Central differences over the flat parameter view.
fn fd_param_grads(mlp: &Mlp, x: &Tensor, t: &Tensor) -> Vec<f32> {
    let mut probe = mlp.clone();
    let mut params = Vec::new();
    probe.write_params(&mut params);
    let mut out = Vec::with_capacity(params.len());
    for i in 0..params.len() {
        let orig = params[i];
        params[i] = orig + FD_H;
        probe.read_params(&params);
        let lp = loss_of(&probe, x, t);
        params[i] = orig - FD_H;
        probe.read_params(&params);
        let lm = loss_of(&probe, x, t);
        params[i] = orig;
        out.push((lp - lm) / (2.0 * FD_H));
    }
    probe.read_params(&params);
    out
}

/// Central differences over the input entries.
fn fd_input_grads(mlp: &Mlp, x: &Tensor, t: &Tensor) -> Vec<f32> {
    let mut probe = x.clone();
    (0..x.numel())
        .map(|i| {
            let orig = probe.data()[i];
            probe.data_mut()[i] = orig + FD_H;
            let lp = loss_of(mlp, &probe, t);
            probe.data_mut()[i] = orig - FD_H;
            let lm = loss_of(mlp, &probe, t);
            probe.data_mut()[i] = orig;
            (lp - lm) / (2.0 * FD_H)
        })
        .collect()
}

#[test]
fn mlp_param_gradients_match_central_differences() {
    check("mlp dW/db == central differences", 25, |rng| {
        let mlp = random_smooth_mlp(rng);
        let b = gen_range(rng, 1, 3);
        let x = Tensor::new(&[b, mlp.layers[0].in_dim()],
                            gen_vec(rng, b * mlp.layers[0].in_dim(), 1.0)).unwrap();
        let dout = mlp.layers.last().unwrap().out_dim();
        let t = Tensor::new(&[b, dout], gen_vec(rng, b * dout, 1.0)).unwrap();
        let (analytic, _) = analytic_grads(&mlp, &x, &t);
        let fd = fd_param_grads(&mlp, &x, &t);
        prop_assert_close(&analytic, &fd, TOL)
    });
}

#[test]
fn mlp_input_gradients_match_central_differences() {
    check("mlp dX == central differences", 25, |rng| {
        let mlp = random_smooth_mlp(rng);
        let b = gen_range(rng, 1, 3);
        let x = Tensor::new(&[b, mlp.layers[0].in_dim()],
                            gen_vec(rng, b * mlp.layers[0].in_dim(), 1.0)).unwrap();
        let dout = mlp.layers.last().unwrap().out_dim();
        let t = Tensor::new(&[b, dout], gen_vec(rng, b * dout, 1.0)).unwrap();
        let (_, dx) = analytic_grads(&mlp, &x, &t);
        let fd = fd_input_grads(&mlp, &x, &t);
        prop_assert_close(dx.data(), &fd, TOL)
    });
}

#[test]
fn relu_gradients_away_from_the_kink() {
    // constructed so every pre-activation stays ≥ 0.3 from zero: an FD step
    // of 1e-2 on any single parameter or input moves a pre-activation by at
    // most ~2e-2, so no branch flips mid-difference
    let mlp = Mlp {
        layers: vec![
            Linear {
                w: Tensor::new(&[2, 2], vec![1.0, -0.8, 0.6, 1.2]).unwrap(),
                b: vec![0.5, -0.4],
                act: Act::Relu,
            },
            Linear {
                w: Tensor::new(&[2, 1], vec![0.9, -1.1]).unwrap(),
                b: vec![0.3],
                act: Act::Id,
            },
        ],
    };
    let x = Tensor::new(&[2, 2], vec![1.0, 1.5, -1.2, 0.8]).unwrap();
    let t = Tensor::new(&[2, 1], vec![0.25, -0.5]).unwrap();
    let (analytic, dx) = analytic_grads(&mlp, &x, &t);
    let fd = fd_param_grads(&mlp, &x, &t);
    prop_assert_close(&analytic, &fd, TOL).unwrap();
    let fd_x = fd_input_grads(&mlp, &x, &t);
    prop_assert_close(dx.data(), &fd_x, TOL).unwrap();
}

#[test]
fn prelu_gradients_match_central_differences() {
    // loss = Σ r ⊙ prelu(x): dL/dy = r exactly, so the kernel under test is
    // isolated. Inputs are pushed ≥ 0.25 away from the kink.
    check("prelu dalpha/dx == central differences", 25, |rng| {
        let (b, c, h, w) = (
            gen_range(rng, 1, 2),
            gen_range(rng, 1, 3),
            gen_range(rng, 1, 3),
            gen_range(rng, 1, 3),
        );
        let p = PRelu {
            alpha: gen_vec(rng, c, 0.5),
        };
        let n = b * c * h * w;
        let x = Tensor::new(
            &[b, c, h, w],
            gen_vec(rng, n, 1.0)
                .into_iter()
                .map(|v| if v >= 0.0 { v + 0.25 } else { v - 0.25 })
                .collect(),
        )
        .unwrap();
        let r = gen_vec(rng, n, 1.0);
        let loss = |p: &PRelu, x: &Tensor| -> f32 {
            let y = p.forward(x).unwrap();
            y.data().iter().zip(&r).map(|(a, b)| a * b).sum()
        };
        // analytic
        let mut dy = Tensor::new(x.shape(), r.clone()).unwrap();
        let mut dalpha = vec![0.0f32; c];
        prelu_backward(&p, &x, &mut dy, &mut dalpha).unwrap();
        // fd over alpha
        let mut probe = p.clone();
        let fd_alpha: Vec<f32> = (0..c)
            .map(|ci| {
                let orig = probe.alpha[ci];
                probe.alpha[ci] = orig + FD_H;
                let lp = loss(&probe, &x);
                probe.alpha[ci] = orig - FD_H;
                let lm = loss(&probe, &x);
                probe.alpha[ci] = orig;
                (lp - lm) / (2.0 * FD_H)
            })
            .collect();
        prop_assert_close(&dalpha, &fd_alpha, TOL)?;
        // fd over inputs (h small enough not to cross the 0.25 margin)
        let mut px = x.clone();
        let fd_x: Vec<f32> = (0..n)
            .map(|i| {
                let orig = px.data()[i];
                px.data_mut()[i] = orig + 1e-3;
                let lp = loss(&p, &px);
                px.data_mut()[i] = orig - 1e-3;
                let lm = loss(&p, &px);
                px.data_mut()[i] = orig;
                (lp - lm) / 2e-3
            })
            .collect();
        prop_assert_close(dy.data(), &fd_x, TOL)
    });
}

#[test]
fn hyper_input_adjoint_matches_central_differences() {
    // full pipeline: L(z, dz) = mse(mlp([z, dz, eps, s]), t) — the adjoint
    // must chain mlp_backward's dX through hyper_input_backward
    check("hyper concat adjoint == central differences", 15, |rng| {
        let d = gen_range(rng, 1, 3);
        let b = gen_range(rng, 1, 3);
        let mut mlp = random_smooth_mlp(rng);
        // force matching in/out dims for the assembled input
        let out0 = mlp.layers[0].out_dim();
        mlp.layers[0] = random_linear(rng, 2 * d + 2, out0, Act::Tanh);
        let last_in = mlp.layers.last().unwrap().in_dim();
        *mlp.layers.last_mut().unwrap() = random_linear(rng, last_in, d, Act::Id);
        let z = Tensor::new(&[b, d], gen_vec(rng, b * d, 1.0)).unwrap();
        let dz = Tensor::new(&[b, d], gen_vec(rng, b * d, 1.0)).unwrap();
        let t = Tensor::new(&[b, d], gen_vec(rng, b * d, 1.0)).unwrap();
        let (eps, s) = (0.125f32, 0.4f32);
        let loss = |z: &Tensor, dz: &Tensor| -> f32 {
            let mut x = Tensor::zeros(&[b, 2 * d + 2]);
            hyper_input_into(eps, s, z, dz, &mut x).unwrap();
            loss_of(&mlp, &x, &t)
        };
        // analytic
        let mut x = Tensor::zeros(&[b, 2 * d + 2]);
        hyper_input_into(eps, s, &z, &dz, &mut x).unwrap();
        let (_, dx) = analytic_grads(&mlp, &x, &t);
        let mut dz_adj = Tensor::zeros(&[b, d]);
        let mut ddz_adj = Tensor::zeros(&[b, d]);
        hyper_input_backward(&dx, &mut dz_adj, &mut ddz_adj).unwrap();
        // fd over z and dz
        let fd_over = |which_z: bool| -> Vec<f32> {
            let mut pz = z.clone();
            let mut pdz = dz.clone();
            let n = b * d;
            (0..n)
                .map(|i| {
                    let buf = if which_z {
                        pz.data_mut()
                    } else {
                        pdz.data_mut()
                    };
                    let orig = buf[i];
                    buf[i] = orig + FD_H;
                    let lp = loss(&pz, &pdz);
                    let buf = if which_z {
                        pz.data_mut()
                    } else {
                        pdz.data_mut()
                    };
                    let lm_at = orig - FD_H;
                    buf[i] = lm_at;
                    let lm = loss(&pz, &pdz);
                    let buf = if which_z {
                        pz.data_mut()
                    } else {
                        pdz.data_mut()
                    };
                    buf[i] = orig;
                    (lp - lm) / (2.0 * FD_H)
                })
                .collect()
        };
        prop_assert_close(dz_adj.data(), &fd_over(true), TOL)?;
        prop_assert_close(ddz_adj.data(), &fd_over(false), TOL)
    });
}

#[test]
fn field_input_adjoint_matches_central_differences() {
    // L(z) = mse(mlp([z, timefeat(s)]), t) for both time modes
    check("time-feature concat adjoint == central differences", 15, |rng| {
        for mode in [TimeMode::Concat, TimeMode::Fourier3] {
            let d = gen_range(rng, 1, 3);
            let b = gen_range(rng, 1, 3);
            let width = d + mode.dim();
            let hidden = gen_range(rng, 1, 4);
            let mlp = Mlp {
                layers: vec![
                    random_linear(rng, width, hidden, Act::Tanh),
                    random_linear(rng, hidden, d, Act::Id),
                ],
            };
            let z = Tensor::new(&[b, d], gen_vec(rng, b * d, 1.0)).unwrap();
            let t = Tensor::new(&[b, d], gen_vec(rng, b * d, 1.0)).unwrap();
            let s = 0.3f32;
            let loss = |z: &Tensor| -> f32 {
                let mut x = Tensor::zeros(&[b, width]);
                field_input_into(mode, s, z, &mut x).unwrap();
                loss_of(&mlp, &x, &t)
            };
            let mut x = Tensor::zeros(&[b, width]);
            field_input_into(mode, s, &z, &mut x).unwrap();
            let (_, dx) = analytic_grads(&mlp, &x, &t);
            let mut dz_adj = Tensor::zeros(&[b, d]);
            field_input_backward(mode, &dx, &mut dz_adj).unwrap();
            let mut pz = z.clone();
            let fd: Vec<f32> = (0..b * d)
                .map(|i| {
                    let orig = pz.data()[i];
                    pz.data_mut()[i] = orig + FD_H;
                    let lp = loss(&pz);
                    pz.data_mut()[i] = orig - FD_H;
                    let lm = loss(&pz);
                    pz.data_mut()[i] = orig;
                    (lp - lm) / (2.0 * FD_H)
                })
                .collect();
            prop_assert_close(dz_adj.data(), &fd, TOL)?;
        }
        Ok(())
    });
}
