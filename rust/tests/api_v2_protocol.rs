//! Protocol tests for `api::v2`: golden byte-exact frames, v1↔v2 decode
//! parity, malformed-frame hardening (codec-level AND over a live TCP
//! connection), version negotiation, and an end-to-end pipelined serving
//! test where v0 lines, v1 lines and v2 frames share one port.

use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use hypersolvers::api::v1::{InferReply, InferRequest, InferResponse};
use hypersolvers::api::{v1, v2, ApiError, ErrorCode};
use hypersolvers::coordinator::{server, Engine, EngineConfig, Policy, Priority};
use hypersolvers::runtime::BackendKind;
use hypersolvers::util::fixtures;
use hypersolvers::util::json::{self, Value};

fn native_engine(tag: &str, tasks: &[(&str, usize)], max_wait: Duration) -> Engine {
    let dir = fixtures::temp_native_artifacts(tag, tasks).unwrap();
    Engine::new(EngineConfig {
        artifacts_dir: dir,
        max_wait,
        policy: Policy::MinMacs,
        backend: BackendKind::Native,
        workers: 2,
        ..Default::default()
    })
    .unwrap()
}

/// Watchdog for the socket tests: a wedged server would otherwise hang
/// `cargo test` forever on a blocking read.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let t = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => t.join().unwrap(),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: v2 protocol test did not finish within {secs}s")
        }
    }
}

fn spawn_server(engine: Engine) -> (Arc<Engine>, String) {
    let engine = Arc::new(engine);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    {
        let engine = Arc::clone(&engine);
        thread::spawn(move || {
            let _ = server::serve_listener(engine, listener);
        });
    }
    (engine, addr)
}

/// Assemble the expected frame bytes by hand: prefix + header + LE rows.
fn frame_fixture(kind: u8, header: &str, rows: &[f32]) -> Vec<u8> {
    let mut want = vec![0xB2u8, kind];
    want.extend_from_slice(&(header.len() as u32).to_le_bytes());
    want.extend_from_slice(&((rows.len() * 4) as u32).to_le_bytes());
    want.extend_from_slice(header.as_bytes());
    for x in rows {
        want.extend_from_slice(&x.to_le_bytes());
    }
    want
}

// ---------------------------------------------------------------------------
// Golden frames: the exact bytes of the v2 dialect
// ---------------------------------------------------------------------------

#[test]
fn golden_v2_request_frame() {
    // dyadic values only (exact in f32 and f64), same discipline as the
    // v1 golden lines — the header prints deterministically (BTreeMap)
    let mut req = InferRequest::batch("cnf_rings", 0.25, 2, vec![0.5, -0.75, 0.25, 1.5]);
    req.id = Some(7);
    assert_eq!(
        v2::encode_request(&req),
        frame_fixture(
            v2::KIND_REQUEST,
            r#"{"budget":0.25,"dims":2,"id":7,"rows":2,"task":"cnf_rings","v":2}"#,
            &[0.5, -0.75, 0.25, 1.5],
        )
    );
}

#[test]
fn golden_v2_response_frame() {
    let resp = InferResponse {
        id: 7,
        variant: "hyperheun_k2".into(),
        mape: 0.02,
        nfe: 4,
        latency_us: 812,
        batch_fill: 4,
        samples: 2,
        dims: 2,
        output: vec![1.0, 2.0, 3.0, 4.0],
        trace: None,
    };
    assert_eq!(
        v2::encode_response(&resp),
        frame_fixture(
            v2::KIND_RESPONSE,
            r#"{"batch_fill":4,"dims":2,"id":7,"latency_us":812,"mape":0.02,"nfe":4,"ok":true,"rows":2,"v":2,"variant":"hyperheun_k2"}"#,
            &[1.0, 2.0, 3.0, 4.0],
        )
    );
}

#[test]
fn golden_v2_error_frame_for_every_code() {
    // error frames carry an empty payload and the same frozen code
    // strings as the v1 lines — fixture-checked for all nine codes
    assert_eq!(
        v2::encode_error(Some(9), None, &ApiError::deadline_exceeded("too slow")),
        frame_fixture(
            v2::KIND_ERROR,
            r#"{"code":"deadline_exceeded","error":"too slow","id":9,"ok":false,"v":2}"#,
            &[],
        )
    );
    for code in ErrorCode::ALL {
        let e = ApiError::new(code, format!("m-{code}"));
        let header = format!(
            r#"{{"code":"{code}","error":"m-{code}","id":3,"ok":false,"v":2}}"#
        );
        assert_eq!(
            v2::encode_error(Some(3), None, &e),
            frame_fixture(v2::KIND_ERROR, &header, &[]),
            "{code}"
        );
        // and it decodes back to the typed error, code intact
        let frame = v2::read_frame(&mut &v2::encode_error(Some(3), None, &e)[..]).unwrap();
        match v2::decode_reply(frame).unwrap() {
            InferReply::Err(back) => {
                assert_eq!(back.id, Some(3));
                assert_eq!(back.error, e);
            }
            other => panic!("{other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// v1 ↔ v2 parity
// ---------------------------------------------------------------------------

#[test]
fn v1_and_v2_decode_identical_requests_identically() {
    // every metadata field set: both codecs must produce the same typed
    // request (they share the strict field mapping, and this pins it)
    let mut r = InferRequest::batch("cnf_a", 0.125, 3, vec![0.5; 6]);
    r.id = Some(42);
    r.policy = Some(Policy::MinNfe);
    r.variant = Some("euler_k2".into());
    r.deadline_us = Some(9000);
    r.priority = Priority::Low;
    r.client = Some("tenant-b".into());
    let (via_v1, ver) = v1::decode_request(&v1::encode_request(&r)).unwrap();
    assert_eq!(ver, 1);
    let frame = v2::read_frame(&mut &v2::encode_request(&r)[..]).unwrap();
    let via_v2 = v2::decode_request(frame).unwrap();
    assert_eq!(via_v1, via_v2);
    assert_eq!(via_v2, r);

    // the omission conventions agree too: infinite budget / normal
    // priority / absent id are absent from the v2 header exactly as from
    // the v1 line
    let plain = InferRequest::single("t", f32::INFINITY, vec![1.0, 2.0]);
    let frame = v2::read_frame(&mut &v2::encode_request(&plain)[..]).unwrap();
    for absent in ["budget", "id", "priority", "client", "policy"] {
        assert!(frame.header.get(absent).is_none(), "{absent}");
    }
    let back = v2::decode_request(frame).unwrap();
    assert_eq!(back.budget, f32::INFINITY);
    assert_eq!(back.priority, Priority::Normal);
}

// ---------------------------------------------------------------------------
// Malformed frames over a live connection
// ---------------------------------------------------------------------------

/// Read one reply frame straight off the socket.
fn read_frame_raw(stream: &mut TcpStream) -> v2::Frame {
    v2::read_frame(stream).expect("server should answer with a v2 frame")
}

fn expect_bad_request(frame: v2::Frame) {
    match v2::decode_reply(frame).unwrap() {
        InferReply::Err(e) => assert_eq!(e.error.code, ErrorCode::BadRequest, "{}", e.error),
        other => panic!("expected a bad_request error frame, got {other:?}"),
    }
}

#[test]
fn malformed_frames_get_loud_bad_request_replies_over_tcp() {
    with_watchdog(60, || {
        let engine = native_engine("v2_bad", &[("cnf_a", 4)], Duration::from_millis(1));
        let (_engine, addr) = spawn_server(engine);

        // header length overflow: rejected before any allocation, loudly
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut b = vec![0xB2u8, v2::KIND_REQUEST];
        b.extend_from_slice(&u32::MAX.to_le_bytes()); // header_len
        b.extend_from_slice(&0u32.to_le_bytes()); // payload_len
        s.write_all(&b).unwrap();
        expect_bad_request(read_frame_raw(&mut s));

        // truncated mid-frame: prefix promises 64 header bytes, the
        // stream ends after 8 — a loud bad_request, not a silent hang
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut b = vec![0xB2u8, v2::KIND_REQUEST];
        b.extend_from_slice(&64u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&[b'{'; 8]);
        s.write_all(&b).unwrap();
        s.shutdown(Shutdown::Write).unwrap();
        expect_bad_request(read_frame_raw(&mut s));

        // payload not a whole number of f32s
        let mut s = TcpStream::connect(&addr).unwrap();
        let good = v2::encode_request(&InferRequest::single("cnf_a", 0.5, vec![0.1, 0.2]));
        let mut b = good.clone();
        b[6..10].copy_from_slice(&7u32.to_le_bytes());
        s.write_all(&b).unwrap();
        expect_bad_request(read_frame_raw(&mut s));

        // ragged row payload: header says 2×2, payload carries 3 floats —
        // the frame itself parses, so the connection survives the reject
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut ragged = InferRequest::batch("cnf_a", 0.5, 2, vec![0.1, 0.2, 0.3, 0.4]);
        ragged.input.pop();
        s.write_all(&v2::encode_request(&ragged)).unwrap();
        expect_bad_request(read_frame_raw(&mut s));
        // ...and a good frame on the same connection is still served
        s.write_all(&good).unwrap();
        let frame = read_frame_raw(&mut s);
        assert_eq!(frame.kind, v2::KIND_RESPONSE);
        match v2::decode_reply(frame).unwrap() {
            InferReply::Ok(r) => assert_eq!((r.samples, r.dims), (1, 2)),
            other => panic!("{other:?}"),
        }

        // a well-formed frame whose shape disagrees with the task state
        // gets the engine's shape_mismatch (not bad_request), echoing id
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut wide = InferRequest::single("cnf_a", 0.5, vec![0.0; 5]);
        wide.id = Some(77);
        s.write_all(&v2::encode_request(&wide)).unwrap();
        match v2::decode_reply(read_frame_raw(&mut s)).unwrap() {
            InferReply::Err(e) => {
                assert_eq!(e.id, Some(77));
                assert_eq!(e.error.code, ErrorCode::ShapeMismatch, "{}", e.error);
            }
            other => panic!("{other:?}"),
        }
    });
}

// ---------------------------------------------------------------------------
// Negotiation + end-to-end pipelined serving over v2
// ---------------------------------------------------------------------------

#[test]
fn protocol_cmd_advertises_all_three_versions() {
    with_watchdog(60, || {
        let engine = native_engine("v2_nego", &[("cnf_a", 4)], Duration::from_millis(1));
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();
        let reply = client
            .request(&json::obj(vec![("cmd", json::s("protocol"))]))
            .unwrap();
        assert_eq!(reply.get("ok").and_then(Value::as_bool), Some(true));
        let versions: Vec<f64> = reply
            .get("versions")
            .and_then(Value::as_arr)
            .unwrap()
            .iter()
            .filter_map(Value::as_f64)
            .collect();
        assert_eq!(versions, vec![0.0, 1.0, 2.0]);
        assert!(client.prefer_v2().unwrap(), "negotiation should pick v2");
    });
}

#[test]
fn pipelined_v2_connection_matches_inflight_ids_and_mixes_dialects() {
    with_watchdog(120, || {
        let engine = native_engine(
            "v2_pipe",
            &[("cnf_a", 4), ("cnf_b", 4)],
            Duration::from_millis(1),
        );
        let (engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();

        // a v1 round trip BEFORE negotiation (client still speaks lines)
        match client
            .infer_v1(&InferRequest::single("cnf_a", 0.5, vec![0.1, 0.2]))
            .unwrap()
        {
            InferReply::Ok(r) => assert_eq!(r.samples, 1),
            other => panic!("{other:?}"),
        }

        assert!(client.prefer_v2().unwrap());

        // N=16 v2 frames in flight on one connection: mixed tasks, mixed
        // budgets, mixed row counts, plus two poisoned requests that come
        // back as immediate v2 error frames
        let mut reqs: Vec<InferRequest> = Vec::new();
        for i in 0..16u64 {
            let task = if i % 2 == 0 { "cnf_a" } else { "cnf_b" };
            let budget = [0.5f32, 0.05, 1e-6][(i % 3) as usize];
            let samples = 1 + (i as usize % 3);
            let input: Vec<f32> = (0..samples * 2)
                .map(|j| 0.05 * (i as f32) - 0.03 * j as f32)
                .collect();
            let mut r = InferRequest::batch(task, budget, samples, input);
            r.id = Some(100 + i);
            reqs.push(r);
        }
        let mut bad_task = InferRequest::single("no_such_task", 0.5, vec![0.0, 0.0]);
        bad_task.id = Some(900);
        reqs.insert(5, bad_task);
        let mut bad_shape = InferRequest::single("cnf_a", 0.5, vec![0.0; 5]);
        bad_shape.id = Some(901);
        reqs.insert(11, bad_shape);

        let replies = client.infer_pipelined(&reqs).unwrap();
        assert_eq!(replies.len(), reqs.len());
        for (req, reply) in reqs.iter().zip(&replies) {
            assert_eq!(reply.id(), req.id, "replies re-ordered by id");
            match (req.id, reply) {
                (Some(900), InferReply::Err(e)) => {
                    assert_eq!(e.error.code, ErrorCode::UnknownTask)
                }
                (Some(901), InferReply::Err(e)) => {
                    assert_eq!(e.error.code, ErrorCode::ShapeMismatch)
                }
                (_, InferReply::Ok(r)) => {
                    assert_eq!(r.samples, req.samples, "row count echoed");
                    assert_eq!(r.output.len(), req.samples * 2);
                    assert!(r.output.iter().all(|x| x.is_finite()));
                }
                (id, other) => panic!("request {id:?} got {other:?}"),
            }
        }

        // all three dialects interleave on the SAME connection: a legacy
        // v0 line (answered in the v0 shape, deprecation notice intact)...
        let v0 = client.infer("cnf_a", 0.5, &[0.5, 0.5]).unwrap();
        assert_eq!(v0.get("ok").and_then(Value::as_bool), Some(true), "{v0:?}");
        assert!(v0.get("deprecation").is_some());
        // ...then another v2 frame round trip
        match client
            .infer_v1(&InferRequest::single("cnf_b", 0.05, vec![0.1, 0.2]))
            .unwrap()
        {
            InferReply::Ok(r) => assert_eq!(r.variant, "hyperheun_k2"),
            other => panic!("{other:?}"),
        }

        let m = engine.metrics();
        assert!(
            m.responses.load(std::sync::atomic::Ordering::Relaxed) >= 18,
            "{}",
            m.report()
        );
    });
}

#[test]
fn golden_v2_request_frame_with_trace() {
    // trace rides the frame header under the same omission convention as
    // the v1 line — the frame above (no trace) stays byte-identical
    let mut req = InferRequest::batch("cnf_rings", 0.25, 2, vec![0.5, -0.75, 0.25, 1.5]);
    req.id = Some(7);
    req.trace = Some(42);
    assert_eq!(
        v2::encode_request(&req),
        frame_fixture(
            v2::KIND_REQUEST,
            r#"{"budget":0.25,"dims":2,"id":7,"rows":2,"task":"cnf_rings","trace":42,"v":2}"#,
            &[0.5, -0.75, 0.25, 1.5],
        )
    );
}

#[test]
fn trace_ids_propagate_over_a_negotiated_v2_connection() {
    with_watchdog(60, || {
        let engine = native_engine("v2_trace", &[("cnf_a", 4)], Duration::from_millis(1));
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();
        assert!(client.prefer_v2().unwrap());

        // success frame echoes the client trace id
        let mut req = InferRequest::single("cnf_a", 0.5, vec![0.1, 0.2]);
        req.trace = Some(88_000_001);
        match client.infer_v1(&req).unwrap() {
            InferReply::Ok(r) => assert_eq!(r.trace, Some(88_000_001)),
            other => panic!("{other:?}"),
        }

        // error frame (submit rejection) echoes it too
        let mut bad = InferRequest::single("no_such_task", 0.5, vec![0.1, 0.2]);
        bad.trace = Some(88_000_002);
        match client.infer_v1(&bad).unwrap() {
            InferReply::Err(e) => {
                assert_eq!(e.error.code, ErrorCode::UnknownTask);
                assert_eq!(e.trace, Some(88_000_002));
            }
            other => panic!("{other:?}"),
        }

        // an untraced frame on the same connection stays trace-free
        match client
            .infer_v1(&InferRequest::single("cnf_a", 0.5, vec![0.3, 0.4]))
            .unwrap()
        {
            InferReply::Ok(r) => assert_eq!(r.trace, None),
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn deadline_exceeded_travels_a_v2_frame_with_its_code() {
    with_watchdog(60, || {
        let engine = native_engine(
            "v2_deadline",
            &[("cnf_a", 4)],
            Duration::from_millis(500),
        );
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();
        assert!(client.prefer_v2().unwrap());
        let mut req = InferRequest::single("cnf_a", 0.5, vec![0.1, 0.2]);
        req.deadline_us = Some(1);
        match client.infer_v1(&req).unwrap() {
            InferReply::Err(e) => {
                assert_eq!(e.error.code, ErrorCode::DeadlineExceeded, "{}", e.error)
            }
            other => panic!("expected deadline_exceeded, got {other:?}"),
        }
    });
}
