//! End-to-end train → serialize → serve: residual-fit a HyperMlp on Van der
//! Pol, assert the trained hypersolver's one-step residual beats plain
//! Euler by ≥ 5× on held-out states, export the weights JSON + manifest,
//! and serve all variants through the native backend — the full loop the
//! `hypertrain` CLI automates, pinned as a test so it cannot rot.

use std::path::PathBuf;

use hypersolvers::nn::{AnalyticField, FieldNet};
use hypersolvers::runtime::Manifest;
use hypersolvers::solvers::Tableau;
use hypersolvers::train::{
    base_variant_name, export_trained, hyper_variant_name, one_step_errors, serve_check,
    train_hypersolver, FineRef, StateSampler, TrainConfig,
};
use hypersolvers::util::prng::Rng;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "hsolve_train_e2e_{tag}_{}",
        std::process::id()
    ));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).unwrap();
    }
    dir
}

#[test]
fn trained_hypereuler_beats_euler_5x_and_serves_natively() {
    let field = FieldNet::Analytic(AnalyticField::VanDerPol { mu: 1.0 });
    let cfg = TrainConfig {
        solver: "euler".into(),
        hidden: vec![32, 32],
        steps: 4000,
        batch: 128,
        lr: 3e-3,
        warmup: 50,
        seed: 11,
        s_span: (0.0, 1.0),
        k: 8,
        fine: FineRef::Rk4Substeps(8),
        sampler: StateSampler::UniformBox {
            lo: -2.0,
            hi: 2.0,
            dim: 2,
        },
        eval_every: 100,
        eval_batch: 256,
        patience: 12,
        min_rel_improve: 5e-3,
        // stop as soon as the bar is comfortably cleared — bounds test time
        stop_at_improvement: 8.0,
        log: false,
    };
    let (g, report) = train_hypersolver(&field, &cfg).unwrap();
    assert!(
        report.improvement >= 5.0,
        "trained hypersolver only {:.2}× better than euler (base {:.3e}, hyper {:.3e}) \
         after {} steps",
        report.improvement,
        report.err_base,
        report.err_hyper,
        report.steps_run
    );

    // independent held-out check, fresh states and several s values
    let eps = 1.0 / cfg.k as f32;
    let mut rng = Rng::new(999);
    let tab = Tableau::euler();
    let (mut sum_base, mut sum_hyper) = (0.0f32, 0.0f32);
    for (i, s) in [0.0f32, 0.3, 0.6, 0.875].into_iter().enumerate() {
        let z = cfg.sampler.sample(128, &mut rng).unwrap();
        let (eb, eh) =
            one_step_errors(&field, &g, &tab, cfg.fine, &z, s, eps).unwrap();
        assert!(eb.is_finite() && eh.is_finite(), "s={s} i={i}");
        sum_base += eb;
        sum_hyper += eh;
    }
    assert!(
        sum_base >= 5.0 * sum_hyper,
        "held-out residual across s values: base {sum_base:.3e} vs hyper {sum_hyper:.3e}"
    );

    // export and serve the whole variant family through the native backend
    let dir = temp_dir("vdp");
    export_trained(&dir, "vdp", &field, &g, &cfg, &report, 16).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let task = manifest.task("vdp").unwrap();
    assert_eq!(task.hyper_base, "euler");
    assert!((task.delta as f32 - report.best_val_loss).abs() < 1e-6);
    let hyper_variant = task.variant(&hyper_variant_name(&cfg)).unwrap();
    assert!(hyper_variant.hyper);
    // the measured manifest mapes must rank hyper above plain
    let plain_variant = task.variant(&base_variant_name(&cfg)).unwrap();
    assert!(
        hyper_variant.mape < plain_variant.mape,
        "exported mape: hyper {} vs plain {}",
        hyper_variant.mape,
        plain_variant.mape
    );

    // the canonical train→serialize→serve criterion, shared with the
    // hypertrain binary: errors if any served output is non-finite or the
    // hypersolved variant is no closer to the served dopri5 than plain
    let (d_hyper, d_plain) = serve_check(&dir, "vdp", &cfg, 16).unwrap();
    assert!(d_hyper < d_plain);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trained_weights_roundtrip_through_cnf_model() {
    // a much shorter run: the exported JSON must reload into a CnfModel
    // whose hypernet evaluates bit-identically to the trained one
    let field = FieldNet::Analytic(AnalyticField::Rotation { omega: 1.0 });
    let cfg = TrainConfig {
        steps: 120,
        batch: 32,
        hidden: vec![8],
        eval_every: 40,
        eval_batch: 64,
        fine: FineRef::Rk4Substeps(4),
        sampler: StateSampler::UniformBox {
            lo: -1.5,
            hi: 1.5,
            dim: 2,
        },
        seed: 3,
        ..TrainConfig::default()
    };
    use hypersolvers::ode::VectorField;
    use hypersolvers::solvers::HyperNet;
    let (g, report) = train_hypersolver(&field, &cfg).unwrap();
    let dir = temp_dir("roundtrip");
    let weights = export_trained(&dir, "rot", &field, &g, &cfg, &report, 4).unwrap();
    let model = hypersolvers::nn::CnfModel::load(&weights).unwrap();
    let z = hypersolvers::tensor::Tensor::new(&[2, 2], vec![0.5, -0.25, 1.0, 0.75])
        .unwrap();
    let dz = field.eval(0.0, &z);
    let before = g.eval(0.125, 0.5, &z, &dz);
    let after = model.hyper.eval(0.125, 0.5, &z, &dz);
    assert_eq!(before.data(), after.data(), "weights JSON round trip drifted");
    // and the reloaded field is the same analytic reference
    assert_eq!(
        field.eval(0.3, &z).data(),
        model.field.eval(0.3, &z).data()
    );
    std::fs::remove_dir_all(&dir).ok();
}
