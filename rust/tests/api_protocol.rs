//! Protocol tests for `api::v1`: golden wire lines, v0 back-compat, every
//! error code over the wire, and a pipelined TCP integration test (N
//! requests in flight on one connection, out-of-order completion, ids all
//! matched) against the artifact-free native engine.

use std::net::TcpListener;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use hypersolvers::api::v1::{self, InferReply, InferRequest, InferResponse};
use hypersolvers::api::{ApiError, ErrorCode};
use hypersolvers::coordinator::{server, Engine, EngineConfig, Policy};
use hypersolvers::runtime::BackendKind;
use hypersolvers::util::fixtures;
use hypersolvers::util::json::{self, Value};

fn native_engine(tag: &str, tasks: &[(&str, usize)], max_wait: Duration) -> Engine {
    let dir = fixtures::temp_native_artifacts(tag, tasks).unwrap();
    Engine::new(EngineConfig {
        artifacts_dir: dir,
        max_wait,
        policy: Policy::MinMacs,
        backend: BackendKind::Native,
        workers: 2,
        ..Default::default()
    })
    .unwrap()
}

/// Watchdog for the socket tests: a wedged server would otherwise hang
/// `cargo test` forever on a blocking read.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let t = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => t.join().unwrap(),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: protocol test did not finish within {secs}s")
        }
    }
}

// ---------------------------------------------------------------------------
// Golden lines: the exact bytes of the v1 dialect
// ---------------------------------------------------------------------------

#[test]
fn golden_v1_request_line() {
    // dyadic values only: the wire widens f32 → f64, and a non-dyadic
    // f32 like 0.1 would print its full f64 expansion
    let mut req = InferRequest::batch("cnf_rings", 0.25, 2, vec![0.5, -0.75, 0.25, 1.5]);
    req.id = Some(7);
    req.policy = Some(Policy::MinNfe);
    req.deadline_us = Some(5000);
    assert_eq!(
        json::to_string(&v1::encode_request(&req)),
        r#"{"budget":0.25,"deadline_us":5000,"id":7,"input":[[0.5,-0.75],[0.25,1.5]],"policy":"nfe","task":"cnf_rings","v":1}"#
    );
}

#[test]
fn golden_v1_request_line_with_trace() {
    // the optional trace id is the only difference from the line above:
    // untraced requests stay byte-identical to the pre-trace protocol
    let mut req = InferRequest::batch("cnf_rings", 0.25, 2, vec![0.5, -0.75, 0.25, 1.5]);
    req.id = Some(7);
    req.trace = Some(42);
    assert_eq!(
        json::to_string(&v1::encode_request(&req)),
        r#"{"budget":0.25,"id":7,"input":[[0.5,-0.75],[0.25,1.5]],"task":"cnf_rings","trace":42,"v":1}"#
    );
}

#[test]
fn golden_v1_response_line() {
    let resp = InferResponse {
        id: 7,
        variant: "hyperheun_k2".into(),
        mape: 0.02,
        nfe: 4,
        latency_us: 812,
        batch_fill: 4,
        samples: 2,
        dims: 2,
        output: vec![1.0, 2.0, 3.0, 4.0],
        trace: None,
    };
    assert_eq!(
        json::to_string(&v1::encode_response(&resp, 1)),
        r#"{"batch_fill":4,"id":7,"latency_us":812,"mape":0.02,"nfe":4,"ok":true,"output":[[1,2],[3,4]],"v":1,"variant":"hyperheun_k2"}"#
    );
}

#[test]
fn golden_v1_error_line() {
    let e = ApiError::deadline_exceeded("too slow");
    assert_eq!(
        json::to_string(&v1::encode_error(Some(9), None, &e, 1)),
        r#"{"code":"deadline_exceeded","error":"too slow","id":9,"ok":false,"v":1}"#
    );
    // v0 dialect: no version tag, code still present
    assert_eq!(
        json::to_string(&v1::encode_error(None, None, &ApiError::unknown_cmd("nope"), 0)),
        r#"{"code":"unknown_cmd","error":"nope","ok":false}"#
    );
}

#[test]
fn golden_overloaded_error_line() {
    // the admission-control/shedding rejection is part of the frozen wire
    // contract: clients branch on this exact code string to back off
    let e = ApiError::overloaded("queue past deadline");
    assert_eq!(
        json::to_string(&v1::encode_error(Some(11), None, &e, 1)),
        r#"{"code":"overloaded","error":"queue past deadline","id":11,"ok":false,"v":1}"#
    );
    // a traced request that gets rejected carries its trace id back on the
    // rejection, so clients can line refusals up with their own spans
    assert_eq!(
        json::to_string(&v1::encode_error(Some(11), Some(3), &e, 1)),
        r#"{"code":"overloaded","error":"queue past deadline","id":11,"ok":false,"trace":3,"v":1}"#
    );
}

#[test]
fn every_error_code_round_trips_the_wire() {
    for code in ErrorCode::ALL {
        let e = ApiError::new(code, format!("m-{code}"));
        let line = json::to_string(&v1::encode_error(Some(3), None, &e, 1));
        let back = json::parse(&line).unwrap();
        match v1::decode_reply(&back).unwrap() {
            InferReply::Err(err) => {
                assert_eq!(err.id, Some(3));
                assert_eq!(err.error.code, code);
                assert_eq!(err.error.message, format!("m-{code}"));
            }
            other => panic!("{other:?}"),
        }
    }
}

#[test]
fn v0_and_v1_lines_decode_to_the_same_typed_request() {
    let v0 = json::parse(r#"{"task":"t","budget":0.1,"input":[0.5,-0.5]}"#).unwrap();
    let v1l = json::parse(r#"{"v":1,"task":"t","budget":0.1,"input":[0.5,-0.5]}"#).unwrap();
    let (r0, ver0) = v1::decode_request(&v0).unwrap();
    let (r1, ver1) = v1::decode_request(&v1l).unwrap();
    assert_eq!(ver0, 0);
    assert_eq!(ver1, 1);
    assert_eq!(r0, r1);
}

// ---------------------------------------------------------------------------
// Pipelined TCP integration
// ---------------------------------------------------------------------------

fn spawn_server(engine: Engine) -> (Arc<Engine>, String) {
    let engine = Arc::new(engine);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    {
        let engine = Arc::clone(&engine);
        thread::spawn(move || {
            let _ = server::serve_listener(engine, listener);
        });
    }
    (engine, addr)
}

#[test]
fn pipelined_connection_matches_n_inflight_ids() {
    with_watchdog(120, || {
        let engine = native_engine(
            "pipe",
            &[("cnf_a", 4), ("cnf_b", 4)],
            Duration::from_millis(1),
        );
        let (engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();

        // N=16 in flight on one connection: mixed tasks (so batches land
        // on distinct queues and can complete out of order), mixed budgets
        // (distinct variants even within a task), mixed row counts, and a
        // couple of guaranteed-immediate error replies interleaved
        let mut reqs: Vec<InferRequest> = Vec::new();
        for i in 0..16u64 {
            let task = if i % 2 == 0 { "cnf_a" } else { "cnf_b" };
            let budget = [0.5f32, 0.05, 1e-6][(i % 3) as usize];
            let samples = 1 + (i as usize % 3); // 1..=3 rows, cap is 4
            let input: Vec<f32> = (0..samples * 2)
                .map(|j| 0.05 * (i as f32) - 0.03 * j as f32)
                .collect();
            let mut r = InferRequest::batch(task, budget, samples, input);
            r.id = Some(100 + i);
            reqs.push(r);
        }
        // two bad requests mid-pipeline: unknown task and a wrong shape
        let mut bad_task = InferRequest::single("no_such_task", 0.5, vec![0.0, 0.0]);
        bad_task.id = Some(900);
        reqs.insert(5, bad_task);
        let mut bad_shape = InferRequest::single("cnf_a", 0.5, vec![0.0; 5]);
        bad_shape.id = Some(901);
        reqs.insert(11, bad_shape);

        let replies = client.infer_pipelined(&reqs).unwrap();
        assert_eq!(replies.len(), reqs.len());
        // the two poisoned requests must come back as errors (not be
        // silently served), in their request-order slots
        assert!(matches!(&replies[5], InferReply::Err(_)), "{:?}", replies[5]);
        assert!(matches!(&replies[11], InferReply::Err(_)), "{:?}", replies[11]);
        for (req, reply) in reqs.iter().zip(&replies) {
            assert_eq!(reply.id(), req.id, "replies re-ordered by id");
            match (req.id, reply) {
                (Some(900), InferReply::Err(e)) => {
                    assert_eq!(e.error.code, ErrorCode::UnknownTask)
                }
                (Some(901), InferReply::Err(e)) => {
                    assert_eq!(e.error.code, ErrorCode::ShapeMismatch)
                }
                (_, InferReply::Ok(r)) => {
                    assert_eq!(r.samples, req.samples, "row count echoed");
                    assert_eq!(r.dims, 2);
                    assert_eq!(r.output.len(), req.samples * 2);
                    assert!(r.output.iter().all(|x| x.is_finite()));
                    assert!(r.latency_us > 0);
                }
                (id, other) => panic!("request {id:?} got {other:?}"),
            }
        }

        // a legacy v0 line on the same (still-pipelined) connection is
        // answered in the v0 dialect with the deprecation notice
        let v0 = client.infer("cnf_a", 0.5, &[0.5, 0.5]).unwrap();
        assert_eq!(v0.get("ok").and_then(Value::as_bool), Some(true), "{v0:?}");
        assert!(v0.get("deprecation").is_some());
        assert!(v0.get("v").is_none());

        // and a typed v1 single round trip still works afterwards
        match client
            .infer_v1(&InferRequest::single("cnf_b", 0.05, vec![0.1, 0.2]))
            .unwrap()
        {
            InferReply::Ok(r) => assert_eq!(r.variant, "hyperheun_k2"),
            other => panic!("{other:?}"),
        }

        let m = engine.metrics();
        assert!(
            m.responses.load(std::sync::atomic::Ordering::Relaxed) >= 18,
            "{}",
            m.report()
        );
    });
}

#[test]
fn deadline_exceeded_travels_the_wire_with_its_code() {
    with_watchdog(60, || {
        // cap 4 + long max_wait: a lone request only flushes at its own
        // deadline → structured deadline_exceeded reply
        let engine = native_engine(
            "pipe_deadline",
            &[("cnf_a", 4)],
            Duration::from_millis(500),
        );
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();
        let mut req = InferRequest::single("cnf_a", 0.5, vec![0.1, 0.2]);
        req.deadline_us = Some(1);
        match client.infer_v1(&req).unwrap() {
            InferReply::Err(e) => {
                assert_eq!(e.error.code, ErrorCode::DeadlineExceeded, "{}", e.error)
            }
            other => panic!("expected deadline_exceeded, got {other:?}"),
        }
    });
}

#[test]
fn protocol_version_negotiation_rejects_unknown_versions() {
    with_watchdog(60, || {
        let engine = native_engine("pipe_ver", &[("cnf_a", 4)], Duration::from_millis(1));
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();
        let reply = client
            .request(&json::parse(r#"{"v":3,"task":"cnf_a","input":[1,2]}"#).unwrap())
            .unwrap();
        assert_eq!(reply.get("ok").and_then(Value::as_bool), Some(false));
        assert_eq!(
            reply.get("code").and_then(Value::as_str),
            Some("bad_request"),
            "{reply:?}"
        );
        // invalid JSON gets a structured bad_request too, and the
        // connection survives for the next request
        let reply = client.request(&json::parse(r#""not an object""#).unwrap()).unwrap();
        assert_eq!(
            reply.get("code").and_then(Value::as_str),
            Some("bad_request"),
            "{reply:?}"
        );
        let ok = client.infer("cnf_a", 0.5, &[0.1, 0.2]).unwrap();
        assert_eq!(ok.get("ok").and_then(Value::as_bool), Some(true));
    });
}

// ---------------------------------------------------------------------------
// Request tracing: wire echo + the cmd:"trace" span surface
// ---------------------------------------------------------------------------

#[test]
fn traced_request_yields_an_ordered_span_via_cmd_trace() {
    with_watchdog(60, || {
        let engine = native_engine("pipe_trace", &[("cnf_a", 4)], Duration::from_millis(1));
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();

        // success reply echoes the client-supplied trace id
        let mut req = InferRequest::single("cnf_a", 0.05, vec![0.1, 0.2]);
        req.trace = Some(77_000_001);
        match client.infer_v1(&req).unwrap() {
            InferReply::Ok(r) => assert_eq!(r.trace, Some(77_000_001)),
            other => panic!("{other:?}"),
        }

        // an error reply (submit rejection — same arm that answers
        // overloaded rejects) echoes it too
        let mut bad = InferRequest::single("no_such_task", 0.05, vec![0.1, 0.2]);
        bad.trace = Some(77_000_002);
        match client.infer_v1(&bad).unwrap() {
            InferReply::Err(e) => {
                assert_eq!(e.error.code, ErrorCode::UnknownTask);
                assert_eq!(e.trace, Some(77_000_002));
            }
            other => panic!("{other:?}"),
        }

        // the span surface: the traced request must be in the ring with
        // monotonically ordered stage stamps and real solver work
        let reply = client
            .request(&json::parse(r#"{"cmd":"trace"}"#).unwrap())
            .unwrap();
        assert_eq!(reply.get("ok").and_then(Value::as_bool), Some(true), "{reply:?}");
        let spans = reply.get("spans").and_then(Value::as_arr).expect("spans array");
        let span = spans
            .iter()
            .find(|s| s.get("trace").and_then(Value::as_f64) == Some(77_000_001.0))
            .expect("traced span in cmd:\"trace\"");
        let at = |k: &str| {
            span.get(k)
                .and_then(Value::as_f64)
                .unwrap_or_else(|| panic!("span missing {k}: {span:?}"))
        };
        let stamps = [
            at("submit_us"),
            at("enqueue_us"),
            at("pop_us"),
            at("exec_start_us"),
            at("exec_end_us"),
            at("reply_us"),
        ];
        for w in stamps.windows(2) {
            assert!(w[0] <= w[1], "stage stamps out of order: {stamps:?}");
        }
        assert!(at("nfe") > 0.0, "span must carry solver NFE: {span:?}");
        assert_eq!(span.get("task").and_then(Value::as_str), Some("cnf_a"));
        assert_eq!(span.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(span.get("rows").and_then(Value::as_f64), Some(1.0));
    });
}

#[test]
fn pipelined_requests_keep_distinct_trace_ids() {
    with_watchdog(120, || {
        let engine = native_engine(
            "pipe_trace_ids",
            &[("cnf_a", 4), ("cnf_b", 4)],
            Duration::from_millis(1),
        );
        let (_engine, addr) = spawn_server(engine);
        let mut client = server::Client::connect(&addr).unwrap();

        // distinct in-flight requests (mixed tasks/budgets, so completions
        // can reorder) must each come back under their own trace id, and
        // untraced requests interleaved among them stay trace-free
        let mut reqs: Vec<InferRequest> = Vec::new();
        for i in 0..12u64 {
            let task = if i % 2 == 0 { "cnf_a" } else { "cnf_b" };
            let budget = [0.5f32, 0.05][(i % 2) as usize];
            let mut r = InferRequest::single(task, budget, vec![0.1, 0.2]);
            r.id = Some(300 + i);
            r.trace = (i % 3 != 2).then_some(5000 + i);
            reqs.push(r);
        }
        let replies = client.infer_pipelined(&reqs).unwrap();
        assert_eq!(replies.len(), reqs.len());
        for (req, reply) in reqs.iter().zip(&replies) {
            assert_eq!(reply.id(), req.id);
            match reply {
                InferReply::Ok(r) => {
                    assert_eq!(r.trace, req.trace, "trace follows its own request")
                }
                other => panic!("{other:?}"),
            }
        }
    });
}
