//! Workspace hot-path parity: every `_into` / in-place / `_ws` entry point
//! must be **bit-identical** to its pure counterpart, across random shapes
//! and with workspaces reused (dirty) between calls. This is the contract
//! that lets the runtime serve from reusable buffers without changing a
//! single output bit relative to the original allocating implementation.

use hypersolvers::nn::layers::Mlp;
use hypersolvers::nn::{Act, HyperMlp, Linear, MlpField, TimeMode};
use hypersolvers::ode::{Rotation, VanDerPol, VectorField};
use hypersolvers::solvers::{
    adaptive, adaptive_ws, dopri5, dopri5_ws, odeint_fixed, odeint_fixed_traj, odeint_fixed_ws,
    odeint_hyper, odeint_hyper_adaptive, odeint_hyper_adaptive_ws, odeint_hyper_ws, psi, rk_step,
    AdaptiveOpts, HyperNet, RkWorkspace, Tableau,
};
use hypersolvers::tensor::{Tensor, Workspace};
use hypersolvers::util::propkit::{check, gen_range, gen_vec, prop_assert};
use hypersolvers::util::prng::Rng;

fn random_linear(rng: &mut Rng, din: usize, dout: usize, act: Act) -> Linear {
    Linear {
        w: Tensor::new(&[din, dout], gen_vec(rng, din * dout, 0.5)).unwrap(),
        b: gen_vec(rng, dout, 0.5),
        act,
    }
}

/// A random (d → d) field MLP with time-concat input, as the exporter
/// produces.
fn random_field(rng: &mut Rng, d: usize, hidden: usize) -> MlpField {
    MlpField {
        mlp: Mlp {
            layers: vec![
                random_linear(rng, d + 1, hidden, Act::Tanh),
                random_linear(rng, hidden, d, Act::Id),
            ],
        },
        time_mode: TimeMode::Concat,
    }
}

/// A random hyper net over [z, dz, eps, s].
fn random_hyper(rng: &mut Rng, d: usize, hidden: usize) -> HyperMlp {
    HyperMlp {
        mlp: Mlp {
            layers: vec![
                random_linear(rng, 2 * d + 2, hidden, Act::Tanh),
                random_linear(rng, hidden, d, Act::Id),
            ],
        },
    }
}

// ---------------------------------------------------------------------------
// kernel parity
// ---------------------------------------------------------------------------

#[test]
fn matmul_into_bit_identical_with_dirty_workspace_tensors() {
    let mut ws = Workspace::new();
    check("matmul_into == matmul (pooled out)", 40, |rng| {
        let (m, k, n) = (
            gen_range(rng, 1, 9),
            gen_range(rng, 1, 9),
            gen_range(rng, 1, 9),
        );
        let a = Tensor::new(&[m, k], gen_vec(rng, m * k, 1.0)).unwrap();
        let b = Tensor::new(&[k, n], gen_vec(rng, k * n, 1.0)).unwrap();
        // the out tensor cycles through the pool carrying stale contents
        let mut out = ws.take_tensor(&[m, n]);
        a.matmul_into(&b, &mut out).unwrap();
        let same = out.data() == a.matmul(&b).unwrap().data();
        ws.give_tensor(out);
        prop_assert(same, "matmul_into diverged from matmul")
    });
}

#[test]
fn mlp_and_field_eval_into_bit_identical_across_random_nets() {
    let mut ws = Workspace::new();
    check("eval_into == eval (random nets)", 25, |rng| {
        let d = gen_range(rng, 1, 4);
        let hidden = gen_range(rng, 1, 6);
        let b = gen_range(rng, 1, 5);
        let field = random_field(rng, d, hidden);
        let z = Tensor::new(&[b, d], gen_vec(rng, b * d, 1.0)).unwrap();
        let s = rng.normal_f32();
        let pure = field.eval(s, &z);
        let mut out = ws.take_tensor(&[b, d]);
        field.eval_into(s, &z, &mut out, &mut ws);
        let same = out.data() == pure.data();
        ws.give_tensor(out);
        prop_assert(same, "MlpField::eval_into diverged")?;

        let g = random_hyper(rng, d, hidden);
        let dz = field.eval(s, &z);
        let gp = g.eval(0.125, s, &z, &dz);
        let mut gout = ws.take_tensor(&[b, d]);
        g.eval_into(0.125, s, &z, &dz, &mut gout, &mut ws);
        let same = gout.data() == gp.data();
        ws.give_tensor(gout);
        prop_assert(same, "HyperMlp::eval_into diverged")
    });
}

// ---------------------------------------------------------------------------
// solver parity: _ws entry points vs pure wrappers, reused workspace
// ---------------------------------------------------------------------------

#[test]
fn odeint_fixed_ws_reused_across_shapes_and_tableaus() {
    let mut ws = RkWorkspace::new();
    check("odeint_fixed_ws == odeint_fixed", 20, |rng| {
        let b = gen_range(rng, 1, 4);
        let z0 = Tensor::new(&[b, 2], gen_vec(rng, b * 2, 1.0)).unwrap();
        let f = Rotation { omega: 1.3 };
        for tab in [Tableau::euler(), Tableau::heun(), Tableau::rk4()] {
            let k = gen_range(rng, 1, 9);
            let pure = odeint_fixed(&f, &z0, (0.0, 1.0), k, &tab).unwrap();
            let via_ws = odeint_fixed_ws(&f, &z0, (0.0, 1.0), k, &tab, &mut ws)
                .unwrap()
                .clone();
            prop_assert(
                via_ws == pure,
                format!("{} k={k}: ws result diverged", tab.name),
            )?;
        }
        Ok(())
    });
}

#[test]
fn solver_results_identical_for_override_and_fallback_eval_into() {
    // a field with a hand-written eval_into vs the same dynamics through a
    // closure (which uses the default eval_into fallback): every solver
    // must produce the same bits either way
    let mut rng = Rng::new(42);
    let d = 2;
    let field = random_field(&mut rng, d, 5);
    let field_ref = &field;
    let closure = move |s: f32, z: &Tensor| field_ref.eval(s, z);
    let z0 = Tensor::new(&[3, d], gen_vec(&mut rng, 3 * d, 1.0)).unwrap();

    for k in [1usize, 3, 7] {
        for tab in [Tableau::euler(), Tableau::heun(), Tableau::rk4()] {
            let a = odeint_fixed(&field, &z0, (0.0, 1.0), k, &tab).unwrap();
            let b = odeint_fixed(&closure, &z0, (0.0, 1.0), k, &tab).unwrap();
            assert_eq!(a, b, "{} k={k}", tab.name);
        }
    }
    let opts = AdaptiveOpts::with_tol(1e-5);
    let a = dopri5(&field, &z0, (0.0, 1.0), &opts).unwrap();
    let b = dopri5(&closure, &z0, (0.0, 1.0), &opts).unwrap();
    assert_eq!(a.z, b.z);
    assert_eq!((a.nfe, a.accepted, a.rejected), (b.nfe, b.accepted, b.rejected));
}

#[test]
fn hyper_ws_and_adaptive_ws_match_pure() {
    let mut rng = Rng::new(7);
    let d = 2;
    let field = random_field(&mut rng, d, 4);
    let g = random_hyper(&mut rng, d, 4);
    let z0 = Tensor::new(&[2, d], gen_vec(&mut rng, 2 * d, 1.0)).unwrap();
    let mut ws = RkWorkspace::new();

    for k in [1usize, 4, 9] {
        for tab in [Tableau::euler(), Tableau::heun()] {
            let pure = odeint_hyper(&field, &g, &z0, (0.0, 1.0), k, &tab).unwrap();
            let via = odeint_hyper_ws(&field, &g, &z0, (0.0, 1.0), k, &tab, &mut ws)
                .unwrap()
                .clone();
            assert_eq!(via, pure, "hyper {} k={k}", tab.name);
        }
    }

    let opts = AdaptiveOpts::with_tol(1e-4);
    let pure = dopri5(&field, &z0, (0.0, 1.0), &opts).unwrap();
    let via = dopri5_ws(&field, &z0, (0.0, 1.0), &opts, &mut ws).unwrap();
    assert_eq!(via.z, pure.z);
    assert_eq!(via.nfe, pure.nfe);
    assert_eq!(via.accepted, pure.accepted);
    assert_eq!(via.rejected, pure.rejected);

    let pure = adaptive(&field, &z0, (0.0, 1.0), &Tableau::bs32(), &opts).unwrap();
    let via = adaptive_ws(&field, &z0, (0.0, 1.0), &Tableau::bs32(), &opts, &mut ws).unwrap();
    assert_eq!(via.z, pure.z);

    let pure =
        odeint_hyper_adaptive(&field, &g, &z0, (0.0, 1.0), &Tableau::euler(), &opts).unwrap();
    let via = odeint_hyper_adaptive_ws(
        &field,
        &g,
        &z0,
        (0.0, 1.0),
        &Tableau::euler(),
        &opts,
        &mut ws,
    )
    .unwrap();
    assert_eq!(via.z, pure.z);
    assert_eq!(via.nfe, pure.nfe);
}

#[test]
fn wrappers_against_handrolled_reference_loop() {
    // regression anchor: the historical allocating implementation, inlined
    // here, must keep agreeing with the workspace-backed public APIs
    fn reference_odeint<F: VectorField>(
        f: &F,
        z0: &Tensor,
        span: (f32, f32),
        steps: usize,
        tab: &Tableau,
    ) -> Tensor {
        let eps = (span.1 - span.0) / steps as f32;
        let mut z = z0.clone();
        for k in 0..steps {
            let s = span.0 + k as f32 * eps;
            // stages
            let mut stages: Vec<Tensor> = Vec::new();
            for i in 0..tab.stages() {
                let mut zi = z.clone();
                for (j, &aij) in tab.a[i].iter().enumerate() {
                    if aij != 0.0 {
                        zi.axpy(eps * aij, &stages[j]).unwrap();
                    }
                }
                stages.push(f.eval(s + tab.c[i] * eps, &zi));
            }
            // psi
            let mut acc = Tensor::zeros(z.shape());
            for (bi, ri) in tab.b.iter().zip(&stages) {
                if *bi != 0.0 {
                    acc.axpy(*bi, ri).unwrap();
                }
            }
            z.axpy(eps, &acc).unwrap();
        }
        z
    }

    let f = VanDerPol { mu: 1.5 };
    let z0 = Tensor::new(&[2, 2], vec![1.0, 0.3, -0.4, 0.8]).unwrap();
    for tab in [Tableau::euler(), Tableau::midpoint(), Tableau::rk4()] {
        let want = reference_odeint(&f, &z0, (0.0, 1.0), 16, &tab);
        let got = odeint_fixed(&f, &z0, (0.0, 1.0), 16, &tab).unwrap();
        assert_eq!(got, want, "{}", tab.name);
    }

    // psi / rk_step consistency survives the rewrite
    let p = psi(&f, &Tableau::heun(), 0.2, &z0, 0.1).unwrap();
    let mut manual = z0.clone();
    manual.axpy(0.1, &p).unwrap();
    assert_eq!(manual, rk_step(&f, &Tableau::heun(), 0.2, &z0, 0.1).unwrap());

    // trajectory endpoints equal terminal solve
    let traj = odeint_fixed_traj(&f, &z0, (0.0, 1.0), 8, &Tableau::rk4()).unwrap();
    assert_eq!(
        traj.last().unwrap(),
        &odeint_fixed(&f, &z0, (0.0, 1.0), 8, &Tableau::rk4()).unwrap()
    );
}
