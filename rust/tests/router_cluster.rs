//! Cluster routing tests: a [`Router`] fronting a [`LocalCluster`] of
//! engine nodes. Covers the tentpole acceptance suite — mixed v0/v1/v2
//! dialects pipelined through one router connection with every reply
//! id-correlated in its sender's dialect — plus health-aware failover
//! (first ring node down, request still succeeds within its deadline),
//! exhausted-failover `upstream_unavailable`, merged `cmd:"metrics"`,
//! poller-driven ejection, loud client read timeouts, and the router's
//! own graceful shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use hypersolvers::api::v1::{self, InferReply, InferRequest};
use hypersolvers::api::{v2, ErrorCode};
use hypersolvers::coordinator::server::Client;
use hypersolvers::router::{Ring, Router, RouterConfig};
use hypersolvers::util::cluster::LocalCluster;
use hypersolvers::util::json::{self, Value};

/// Watchdog: a wedged router or node would otherwise hang `cargo test`
/// forever on a blocking socket read.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, f: F) {
    let (tx, rx) = mpsc::channel();
    let t = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => t.join().unwrap(),
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: cluster test did not finish within {secs}s")
        }
    }
}

/// The test router profile: fast polls so ejection happens within a test
/// budget, short connect bound so failover is quick.
fn test_cfg(nodes: Vec<String>) -> RouterConfig {
    RouterConfig {
        nodes,
        vnodes: 64,
        eject_after: 2,
        poll_interval: Duration::from_millis(50),
        retries: 2,
        connect_timeout: Duration::from_millis(500),
        probe_read_timeout: Duration::from_secs(2),
    }
}

/// Bind port 0, serve the router on its own thread, return the address.
fn spawn_router(cfg: RouterConfig) -> (Arc<Router>, String, thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let router = Arc::new(Router::new(cfg));
    let handle = {
        let r = Arc::clone(&router);
        thread::spawn(move || {
            let _ = r.serve_listener(listener);
        })
    };
    (router, addr, handle)
}

fn connect_client(addr: &str) -> Client {
    Client::connect_with(
        addr,
        Some(Duration::from_secs(2)),
        Some(Duration::from_secs(60)),
    )
    .unwrap()
}

/// One downstream message in whatever dialect it arrived: sniff the first
/// byte exactly like the server does.
enum Msg {
    Line(Value),
    Frame(v2::Frame),
}

fn read_msg(reader: &mut BufReader<TcpStream>) -> Msg {
    let first = *reader
        .fill_buf()
        .unwrap()
        .first()
        .expect("router closed the connection");
    if first == v2::FRAME_MAGIC {
        Msg::Frame(v2::read_frame(reader).unwrap())
    } else {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0);
        Msg::Line(json::parse(&line).unwrap())
    }
}

// ---------------------------------------------------------------------------
// Tentpole acceptance: mixed dialects, one router connection, ids correlated
// ---------------------------------------------------------------------------

#[test]
fn mixed_dialects_pipeline_through_the_router_id_correlated() {
    with_watchdog(120, || {
        let cluster =
            LocalCluster::spawn(3, "router_mixed", &[("cnf_a", 4), ("cnf_b", 4)]).unwrap();
        let (_router, addr, _h) = spawn_router(test_cfg(cluster.addrs()));

        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        // six pipelined v1 lines across both tasks (they hash to ring
        // positions independently), ids chosen by the client
        let v1_ids: Vec<u64> = (0..6).map(|i| 100 + i).collect();
        for (i, &id) in v1_ids.iter().enumerate() {
            let task = if i % 2 == 0 { "cnf_a" } else { "cnf_b" };
            let mut r = InferRequest::single(task, 0.05, vec![0.1 * i as f32, -0.2]);
            r.id = Some(id);
            let mut line = json::to_string(&v1::encode_request(&r));
            line.push('\n');
            writer.write_all(line.as_bytes()).unwrap();
        }
        // one binary v2 frame
        let mut r = InferRequest::single("cnf_b", 0.05, vec![0.3, 0.4]);
        r.id = Some(202);
        writer.write_all(&v2::encode_request(&r)).unwrap();
        // one legacy v0 line (no "v"), last — v0 is strict request→reply
        // order, so the router's reader blocks this connection's *intake*
        // (not the already-dispatched replies) until it settles
        writer
            .write_all(b"{\"task\":\"cnf_a\",\"budget\":0.05,\"input\":[0.5,0.5]}\n")
            .unwrap();

        let mut v1_seen: Vec<u64> = Vec::new();
        let mut v2_seen = 0u32;
        let mut v0_seen = 0u32;
        for _ in 0..8 {
            match read_msg(&mut reader) {
                Msg::Frame(f) => {
                    // the v2 request came back as a v2 frame, same id
                    match v2::decode_reply(f).unwrap() {
                        InferReply::Ok(resp) => {
                            assert_eq!(resp.id, 202);
                            assert_eq!(resp.output.len(), 2);
                        }
                        other => panic!("v2 request failed through the router: {other:?}"),
                    }
                    v2_seen += 1;
                }
                Msg::Line(v) => {
                    if v.get("v").is_none() {
                        // the v0 reply keeps the legacy shape: flat output,
                        // deprecation notice, no version tag
                        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v:?}");
                        assert!(v.get("deprecation").is_some());
                        assert_eq!(
                            v.get("output").and_then(Value::as_arr).map(<[Value]>::len),
                            Some(2)
                        );
                        v0_seen += 1;
                    } else {
                        match v1::decode_reply(&v).unwrap() {
                            InferReply::Ok(resp) => v1_seen.push(resp.id),
                            other => panic!("v1 request failed through the router: {other:?}"),
                        }
                    }
                }
            }
        }
        assert_eq!(v2_seen, 1);
        assert_eq!(v0_seen, 1);
        v1_seen.sort_unstable();
        assert_eq!(v1_seen, v1_ids, "every v1 id answered exactly once");

        // merged metrics through the same connection: counters are summed
        // across all three nodes, per_node carries each node's gauges
        writer.write_all(b"{\"cmd\":\"metrics\"}\n").unwrap();
        let merged = match read_msg(&mut reader) {
            Msg::Line(v) => v,
            Msg::Frame(_) => panic!("metrics reply must be a JSON line"),
        };
        assert_eq!(merged.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(merged.get("merged").and_then(Value::as_bool), Some(true));
        assert_eq!(merged.get("nodes").and_then(Value::as_f64), Some(3.0));
        let per_node = merged.get("per_node").and_then(Value::as_arr).unwrap();
        assert_eq!(per_node.len(), 3);
        for n in per_node {
            assert_eq!(n.get("ok").and_then(Value::as_bool), Some(true), "{n:?}");
        }
        let requests = merged.get("requests").and_then(Value::as_f64).unwrap();
        assert!(
            requests >= 8.0,
            "3 nodes served 8 requests between them, merged says {requests}"
        );
    });
}

// ---------------------------------------------------------------------------
// Failover: first ring node down, retries recover within the deadline
// ---------------------------------------------------------------------------

#[test]
fn retries_recover_when_the_primary_node_is_down() {
    with_watchdog(120, || {
        let mut cluster =
            LocalCluster::spawn(3, "router_failover", &[("cnf_a", 4)]).unwrap();
        let (_router, addr, _h) = spawn_router(test_cfg(cluster.addrs()));

        // kill exactly the node the ring places cnf_a on — the router must
        // discover the dead primary on dispatch and fail over along the
        // ring, all inside the request's own deadline
        let ring = Ring::new(3, 64);
        let primary = ring.primary(Ring::key("cnf_a", None)).unwrap();
        cluster.stop(primary).unwrap();

        let started = Instant::now();
        let mut c = connect_client(&addr);
        let mut req = InferRequest::single("cnf_a", 0.05, vec![0.1, -0.2]);
        req.deadline_us = Some(5_000_000);
        match c.infer_v1(&req).unwrap() {
            InferReply::Ok(resp) => assert_eq!(resp.output.len(), 2),
            other => panic!("failover did not recover: {other:?}"),
        }
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "failover must finish within the request deadline"
        );

        // now kill everything: failover runs out of ring and the client
        // gets the frozen upstream_unavailable code, id still correlated
        cluster.stop_all();
        match c.infer_v1(&req).unwrap() {
            InferReply::Err(e) => {
                assert_eq!(e.error.code, ErrorCode::UpstreamUnavailable, "{e:?}");
                assert!(
                    !e.error.message.is_empty(),
                    "exhausted failover must say what it tried"
                );
            }
            other => panic!("no node is alive, yet the request succeeded: {other:?}"),
        }
    });
}

// ---------------------------------------------------------------------------
// Health: the poller ejects a dead node (visible via the router's health cmd)
// ---------------------------------------------------------------------------

#[test]
fn the_poller_ejects_a_stopped_node() {
    with_watchdog(120, || {
        let mut cluster = LocalCluster::spawn(2, "router_eject", &[("cnf_a", 4)]).unwrap();
        let (router, addr, _h) = spawn_router(test_cfg(cluster.addrs()));
        cluster.stop(1).unwrap();

        // eject_after=2 at a 50 ms cadence: well under this deadline
        let deadline = Instant::now() + Duration::from_secs(15);
        while router.health().healthy(1) && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(20));
        }
        assert!(!router.health().healthy(1), "dead node never ejected");
        assert!(router.health().healthy(0), "live node must stay placed");

        // the ejection is observable on the wire too
        let mut c = connect_client(&addr);
        let v = c.request(&json::obj(vec![("cmd", json::s("health"))])).unwrap();
        assert_eq!(v.get("router").and_then(Value::as_bool), Some(true));
        let nodes = v.get("nodes").and_then(Value::as_arr).unwrap();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].get("healthy").and_then(Value::as_bool), Some(true));
        assert_eq!(nodes[1].get("healthy").and_then(Value::as_bool), Some(false));
    });
}

// ---------------------------------------------------------------------------
// Client timeouts: expiry is a loud error, not an eternal hang
// ---------------------------------------------------------------------------

#[test]
fn client_read_timeout_expires_loudly() {
    with_watchdog(60, || {
        // a server that accepts and then never answers
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            // outlive the client's read timeout, then hang up
            thread::sleep(Duration::from_millis(800));
            drop(stream);
        });
        let mut c = Client::connect_with(
            &addr,
            Some(Duration::from_secs(1)),
            Some(Duration::from_millis(150)),
        )
        .unwrap();
        let err = c
            .request(&json::obj(vec![("cmd", json::s("metrics"))]))
            .unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("timed out") && msg.contains("150ms"),
            "timeout expiry must name the timeout, got: {msg}"
        );
        hold.join().unwrap();
    });
}

// ---------------------------------------------------------------------------
// Router shutdown: loopback-gated, then the accept loop exits
// ---------------------------------------------------------------------------

#[test]
fn router_shutdown_exits_the_accept_loop() {
    with_watchdog(60, || {
        let cluster = LocalCluster::spawn(1, "router_shutdown", &[("cnf_a", 4)]).unwrap();
        let (_router, addr, handle) = spawn_router(test_cfg(cluster.addrs()));

        // sanity: the router proxies before shutdown
        let mut c = connect_client(&addr);
        let reply = c
            .infer_v1(&InferRequest::single("cnf_a", 0.05, vec![0.1, -0.2]))
            .unwrap();
        assert!(matches!(reply, InferReply::Ok(_)), "{reply:?}");

        let v = c.request(&json::obj(vec![("cmd", json::s("shutdown"))])).unwrap();
        assert_eq!(v.get("ok").and_then(Value::as_bool), Some(true), "{v:?}");
        assert_eq!(v.get("shutdown").and_then(Value::as_bool), Some(true));

        // the serve thread exits and the port stops accepting
        handle.join().unwrap();
        assert!(
            Client::connect_with(&addr, Some(Duration::from_millis(300)), None).is_err(),
            "router port must be closed after shutdown"
        );
    });
}
