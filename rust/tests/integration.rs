//! Integration tests over the real artifacts: PJRT round trip, native-vs-PJRT
//! numeric agreement, coordinator end-to-end, TCP server protocol.
//!
//! Every test skips gracefully (with a loud message) when `make artifacts`
//! has not been run, so `cargo test` stays green on a fresh checkout.

use std::sync::Arc;
use std::time::Duration;

use hypersolvers::coordinator::{server, Engine, EngineConfig, Policy};
use hypersolvers::data::blobs;
use hypersolvers::metrics::{accuracy, mape};
use hypersolvers::nn::{CnfModel, ImageModel, TrackingModel};
use hypersolvers::runtime::{Executor, Manifest};
use hypersolvers::solvers::{
    dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, Tableau,
};
use hypersolvers::tensor::Tensor;

fn manifest() -> Option<Manifest> {
    match Manifest::load_default() {
        Ok(m) => {
            if m.quick {
                eprintln!("NOTE: artifacts were built with --quick; tolerances loosened");
            }
            Some(m)
        }
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

/// Runtime gate for XLA-dependent tests: skips (loudly) when no PJRT client
/// can be brought up — e.g. when the workspace builds against the offline
/// `xla` stub crate. The native-backend pipeline tests live in
/// `engine_native.rs` and run everywhere.
fn pjrt() -> bool {
    if hypersolvers::runtime::pjrt_available() {
        true
    } else {
        eprintln!("SKIP: PJRT client unavailable (offline xla stub build)");
        false
    }
}

fn load_blob(m: &Manifest, task: &str, key: &str) -> Tensor {
    let t = m.task(task).unwrap();
    let b = &t.data[key];
    blobs::load_f32(&m.blob_path(b), &b.shape).unwrap()
}

// ---------------------------------------------------------------------------
// PJRT round trip
// ---------------------------------------------------------------------------

#[test]
fn pjrt_full_solve_matches_manifest_mape() {
    let Some(m) = manifest() else { return };
    if !pjrt() {
        return;
    }
    let exec = Executor::spawn().unwrap();
    let h = exec.handle();
    let task = m.task("cnf_rings").unwrap();
    let z0 = load_blob(&m, "cnf_rings", "z0");
    let truth = load_blob(&m, "cnf_rings", "truth");

    for vname in ["heun_k1", "hyperheun_k1", "euler_k4"] {
        let v = task.variant(vname).unwrap();
        h.load(vname, m.hlo_path(&v.hlo)).unwrap();
        let out = h.run(vname, z0.data().to_vec(), &v.in_shape).unwrap();
        let zt = Tensor::new(&v.out_shape, out[0].clone()).unwrap();
        let measured = mape(&zt, &truth).unwrap();
        // rust-side MAPE must reproduce the python-side manifest number
        assert!(
            (measured - v.mape).abs() < 1e-3,
            "{vname}: rust mape {measured} vs manifest {}",
            v.mape
        );
    }
}

#[test]
fn pjrt_dopri5_export_returns_nfe() {
    let Some(m) = manifest() else { return };
    if !pjrt() {
        return;
    }
    let exec = Executor::spawn().unwrap();
    let h = exec.handle();
    let task = m.task("cnf_rings").unwrap();
    let v = task.variant("dopri5").unwrap();
    assert!(v.returns_nfe);
    h.load("d5", m.hlo_path(&v.hlo)).unwrap();
    let z0 = load_blob(&m, "cnf_rings", "z0");
    let out = h.run("d5", z0.data().to_vec(), &v.in_shape).unwrap();
    assert_eq!(out.len(), 2, "dopri5 export returns (z, nfe)");
    let nfe = out[1][0] as u64;
    assert!(nfe > 0 && nfe % 7 == 0, "nfe {nfe}");
    let zt = Tensor::new(&v.out_shape, out[0].clone()).unwrap();
    let truth = load_blob(&m, "cnf_rings", "truth");
    assert!(mape(&zt, &truth).unwrap() < 0.01);
}

// ---------------------------------------------------------------------------
// Native nn path vs PJRT / exported truth
// ---------------------------------------------------------------------------

#[test]
fn native_cnf_field_matches_pjrt_solve() {
    let Some(m) = manifest() else { return };
    if !pjrt() {
        return;
    }
    let task = m.task("cnf_rings").unwrap();
    let model = CnfModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "cnf_rings", "z0");

    // native heun K=4 vs the exported heun_k4 executable
    let native = odeint_fixed(&model.field, &z0, task.s_span, 4, &Tableau::heun()).unwrap();
    let exec = Executor::spawn().unwrap();
    let h = exec.handle();
    let v = task.variant("heun_k4").unwrap();
    h.load("h4", m.hlo_path(&v.hlo)).unwrap();
    let out = h.run("h4", z0.data().to_vec(), &v.in_shape).unwrap();
    let pjrt = Tensor::new(&v.out_shape, out[0].clone()).unwrap();
    let diff = mape(&native, &pjrt).unwrap();
    assert!(diff < 2e-3, "native vs pjrt mape {diff}");
}

#[test]
fn native_hyperheun_beats_heun_at_2_nfe() {
    let Some(m) = manifest() else { return };
    if m.quick {
        return; // quick-mode hypersolvers are untrained
    }
    for density in ["cnf_rings", "cnf_pinwheel", "cnf_checkerboard", "cnf_circles"] {
        let task = m.task(density).unwrap();
        let model = CnfModel::load(&m.weights_path(task)).unwrap();
        let z0 = load_blob(&m, density, "z0");
        let truth = load_blob(&m, density, "truth");
        let heun =
            odeint_fixed(&model.field, &z0, task.s_span, 1, &Tableau::heun()).unwrap();
        let hyper = odeint_hyper(
            &model.field,
            &model.hyper,
            &z0,
            task.s_span,
            1,
            &Tableau::heun(),
        )
        .unwrap();
        let m_heun = mape(&heun, &truth).unwrap();
        let m_hyper = mape(&hyper, &truth).unwrap();
        assert!(
            m_hyper < m_heun,
            "{density}: hyperheun {m_hyper} not better than heun {m_heun}"
        );
    }
}

#[test]
fn native_dopri5_reaches_exported_truth() {
    let Some(m) = manifest() else { return };
    let task = m.task("cnf_rings").unwrap();
    let model = CnfModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "cnf_rings", "z0");
    let truth = load_blob(&m, "cnf_rings", "truth");
    let r = dopri5(&model.field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-6)).unwrap();
    let err = mape(&r.z, &truth).unwrap();
    assert!(err < 2e-3, "native dopri5 mape {err}");
    assert!(r.nfe > 0);
}

#[test]
fn native_image_model_accuracy() {
    let Some(m) = manifest() else { return };
    if m.quick {
        return;
    }
    let task = m.task("img_smnist").unwrap();
    let model = ImageModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "img_smnist", "z0");
    let yref = &task.data["y"];
    let labels = blobs::load_i32(&m.blob_path(yref), yref.shape[0]).unwrap();

    // rk4 K=4 native solve → logits → accuracy ≈ truth_acc from manifest
    let zt = odeint_fixed(&model.field, &z0, task.s_span, 4, &Tableau::rk4()).unwrap();
    let logits = model.hy(&zt).unwrap();
    let acc = accuracy(&logits, &labels).unwrap();
    let want = task.truth_acc.unwrap();
    assert!(
        (acc - want).abs() < 0.1,
        "native acc {acc} vs manifest {want}"
    );

    // hypersolved euler K=2 must beat plain euler K=2 on accuracy
    let ze = odeint_fixed(&model.field, &z0, task.s_span, 2, &Tableau::euler()).unwrap();
    let zh = odeint_hyper(
        &model.field,
        &model.hyper,
        &z0,
        task.s_span,
        2,
        &Tableau::euler(),
    )
    .unwrap();
    let acc_e = accuracy(&model.hy(&ze).unwrap(), &labels).unwrap();
    let acc_h = accuracy(&model.hy(&zh).unwrap(), &labels).unwrap();
    assert!(
        acc_h >= acc_e,
        "hypereuler acc {acc_h} < euler acc {acc_e} at K=2"
    );
}

#[test]
fn native_tracking_model_loads_and_improves() {
    let Some(m) = manifest() else { return };
    if m.quick {
        return;
    }
    let task = m.task("tracking").unwrap();
    let model = TrackingModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "tracking", "z0");
    let truth = load_blob(&m, "tracking", "truth");
    let k = 10;
    let eul = odeint_fixed(&model.field, &z0, task.s_span, k, &Tableau::euler()).unwrap();
    let hyp = odeint_hyper(
        &model.field,
        &model.hyper,
        &z0,
        task.s_span,
        k,
        &Tableau::euler(),
    )
    .unwrap();
    let m_e = mape(&eul, &truth).unwrap();
    let m_h = mape(&hyp, &truth).unwrap();
    assert!(m_h < m_e, "tracking: hyper {m_h} vs euler {m_e} at K={k}");
}

#[test]
fn rust_driven_adaptive_over_pjrt_field() {
    // the hybrid mode: rust dopri5 control loop, XLA field evaluations
    let Some(m) = manifest() else { return };
    if !pjrt() {
        return;
    }
    let task = m.task("cnf_rings").unwrap();
    let exec = Executor::spawn().unwrap();
    let h = exec.handle();
    h.load("field", m.hlo_path(&task.field_hlo)).unwrap();
    let field = hypersolvers::runtime::field_exec::PjrtField::new(
        h,
        "field",
        &task.state_shape,
        task.mac_f,
    );
    let z0 = load_blob(&m, "cnf_rings", "z0");
    let truth = load_blob(&m, "cnf_rings", "truth");
    let r = dopri5(&field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-5)).unwrap();
    let err = mape(&r.z, &truth).unwrap();
    assert!(err < 5e-3, "hybrid dopri5 mape {err}");
    assert!(r.nfe >= 7);
}

// ---------------------------------------------------------------------------
// Coordinator end-to-end
// ---------------------------------------------------------------------------

#[test]
fn engine_serves_mixed_budgets() {
    let Some(m) = manifest() else { return };
    drop(m);
    if !pjrt() {
        return;
    }
    let engine = Engine::new(EngineConfig {
        max_wait: Duration::from_millis(1),
        policy: Policy::MinMacs,
        ..Default::default()
    })
    .unwrap();

    // loose budget → cheap variant; tight → accurate variant
    let loose = engine.infer("cnf_rings", 0.5, vec![0.3, -0.2]).unwrap();
    let tight = engine.infer("cnf_rings", 1e-4, vec![0.3, -0.2]).unwrap();
    assert!(loose.mape <= 0.5);
    assert!(tight.mape <= 1e-4 || tight.variant == "dopri5");
    assert_eq!(loose.output.len(), 2);
    assert_eq!(tight.output.len(), 2);

    // batch of concurrent submissions all get answers
    let handles: Vec<_> = (0..32)
        .map(|i| {
            engine
                .submit("cnf_rings", 0.08, vec![0.01 * i as f32, -0.5])
                .unwrap()
        })
        .collect();
    let mut fills = Vec::new();
    for h in handles {
        let resp = h.wait().unwrap();
        assert!(resp.mape <= 0.08);
        fills.push(resp.batch_fill);
    }
    // dynamic batching really batched something
    assert!(fills.iter().any(|&f| f > 1), "fills {fills:?}");
    assert!(engine.metrics().responses.load(std::sync::atomic::Ordering::Relaxed) >= 34);
}

#[test]
fn engine_rejects_bad_requests() {
    let Some(_m) = manifest() else { return };
    if !pjrt() {
        return;
    }
    let engine = Engine::with_defaults().unwrap();
    let e = engine.submit("no_such_task", 0.1, vec![0.0]).unwrap_err();
    assert_eq!(e.code, hypersolvers::api::ErrorCode::UnknownTask);
    // wrong sample dimension
    let e = engine.submit("cnf_rings", 0.1, vec![0.0; 5]).unwrap_err();
    assert_eq!(e.code, hypersolvers::api::ErrorCode::ShapeMismatch);
}

#[test]
fn tcp_server_protocol() {
    let Some(_m) = manifest() else { return };
    if !pjrt() {
        return;
    }
    let engine = Arc::new(Engine::with_defaults().unwrap());
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let engine = Arc::clone(&engine);
        std::thread::spawn(move || {
            let _ = server::serve_listener(engine, listener);
        });
    }
    let mut client = server::Client::connect(&addr.to_string()).unwrap();

    let tasks = client
        .request(&hypersolvers::util::json::parse(r#"{"cmd":"tasks"}"#).unwrap())
        .unwrap();
    assert_eq!(tasks.get("ok").and_then(|v| v.as_bool()), Some(true));

    let resp = client.infer("cnf_rings", 0.1, &[0.5, 0.5]).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    let out = resp.get("output").unwrap().as_arr().unwrap();
    assert_eq!(out.len(), 2);

    let metrics = client
        .request(&hypersolvers::util::json::parse(r#"{"cmd":"metrics"}"#).unwrap())
        .unwrap();
    assert!(metrics.get("report").unwrap().as_str().unwrap().contains("requests="));

    // malformed request gets a JSON error, not a dropped connection
    let bad = client
        .request(&hypersolvers::util::json::parse(r#"{"task":"nope","input":[1]}"#).unwrap())
        .unwrap();
    assert_eq!(bad.get("ok").and_then(|v| v.as_bool()), Some(false));
}
