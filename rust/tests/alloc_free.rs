//! Proof of the allocation-free hot path: a counting global allocator
//! wraps `System`, and a warm [`RkWorkspace`] solve must perform **zero**
//! heap allocations — not "few", zero — for `odeint_fixed_ws` and
//! `odeint_hyper_ws`, and O(1) per solve (the single result clone) for
//! `dopri5_ws`, independent of step count.
//!
//! Everything lives in ONE `#[test]` on purpose: the counter is global, so
//! concurrent tests in the same binary would pollute each other's windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use hypersolvers::nn::layers::Mlp;
use hypersolvers::nn::{Act, HyperMlp, Linear, MlpField, TimeMode};
use hypersolvers::ode::Rotation;
use hypersolvers::solvers::{
    adaptive_ws, dopri5_ws, odeint_fixed_ws, odeint_hyper_ws, AdaptiveOpts, RkWorkspace, Tableau,
};
use hypersolvers::tensor::Tensor;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// The fixtures' 2-D rotation-flavoured field (dz = [z1 + 0.1s, -z0 + 0.1s])
/// as a real exported-architecture MLP, built without JSON so the test has
/// no parse-time noise.
fn fixture_field() -> MlpField {
    MlpField {
        mlp: Mlp {
            layers: vec![Linear {
                w: Tensor::new(&[3, 2], vec![0.0, -1.0, 1.0, 0.0, 0.1, 0.1]).unwrap(),
                b: vec![0.0, 0.0],
                act: Act::Id,
            }],
        },
        time_mode: TimeMode::Concat,
    }
}

/// g([z, dz, eps, s]) = 0.05 z through a genuine two-layer hyper MLP.
fn fixture_hyper() -> HyperMlp {
    HyperMlp {
        mlp: Mlp {
            layers: vec![
                Linear {
                    w: Tensor::new(
                        &[6, 2],
                        vec![
                            0.05, 0.0, 0.0, 0.05, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                        ],
                    )
                    .unwrap(),
                    b: vec![0.0, 0.0],
                    act: Act::Id,
                },
                Linear {
                    w: Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap(),
                    b: vec![0.0, 0.0],
                    act: Act::Id,
                },
            ],
        },
    }
}

#[test]
fn warm_solver_loops_do_not_touch_the_allocator() {
    let z0 = Tensor::new(&[4, 2], (0..8).map(|i| 0.1 * i as f32 - 0.3).collect()).unwrap();
    let heun = Tableau::heun();
    let rk4 = Tableau::rk4();

    // --- odeint_fixed_ws over an analytic field: exactly 0 allocations ---
    let rot = Rotation { omega: 1.4 };
    let mut ws = RkWorkspace::new();
    let warm = odeint_fixed_ws(&rot, &z0, (0.0, 1.0), 64, &rk4, &mut ws)
        .unwrap()
        .clone();
    let before = allocs();
    {
        let result = odeint_fixed_ws(&rot, &z0, (0.0, 1.0), 64, &rk4, &mut ws).unwrap();
        std::hint::black_box(result.data());
    }
    let fixed_allocs = allocs() - before;
    assert_eq!(
        fixed_allocs, 0,
        "odeint_fixed_ws (analytic field, 64 rk4 steps) allocated {fixed_allocs} times"
    );
    assert_eq!(
        warm,
        odeint_fixed_ws(&rot, &z0, (0.0, 1.0), 64, &rk4, &mut ws)
            .unwrap()
            .clone(),
        "hot path result drifted"
    );

    // --- odeint_fixed_ws over a real MLP field: exactly 0 allocations ---
    let field = fixture_field();
    let mut ws = RkWorkspace::new();
    for _ in 0..2 {
        let _ = odeint_fixed_ws(&field, &z0, (0.0, 1.0), 32, &heun, &mut ws).unwrap();
    }
    let before = allocs();
    {
        let result = odeint_fixed_ws(&field, &z0, (0.0, 1.0), 32, &heun, &mut ws).unwrap();
        std::hint::black_box(result.data());
    }
    let mlp_allocs = allocs() - before;
    assert_eq!(
        mlp_allocs, 0,
        "odeint_fixed_ws (MLP field, 32 heun steps) allocated {mlp_allocs} times"
    );

    // --- odeint_hyper_ws (field + hyper net): exactly 0 allocations ---
    let g = fixture_hyper();
    let mut ws = RkWorkspace::new();
    for _ in 0..2 {
        let _ = odeint_hyper_ws(&field, &g, &z0, (0.0, 1.0), 32, &heun, &mut ws).unwrap();
    }
    let before = allocs();
    {
        let result = odeint_hyper_ws(&field, &g, &z0, (0.0, 1.0), 32, &heun, &mut ws).unwrap();
        std::hint::black_box(result.data());
    }
    let hyper_allocs = allocs() - before;
    assert_eq!(
        hyper_allocs, 0,
        "odeint_hyper_ws (MLP field + hyper, 32 heun steps) allocated {hyper_allocs} times"
    );

    // --- step count must not change the allocation count (per-step = 0) ---
    let mut ws = RkWorkspace::new();
    let _ = odeint_hyper_ws(&field, &g, &z0, (0.0, 1.0), 4, &heun, &mut ws).unwrap();
    let before = allocs();
    let _ = odeint_hyper_ws(&field, &g, &z0, (0.0, 1.0), 4, &heun, &mut ws).unwrap();
    let short = allocs() - before;
    let before = allocs();
    let _ = odeint_hyper_ws(&field, &g, &z0, (0.0, 1.0), 256, &heun, &mut ws).unwrap();
    let long = allocs() - before;
    assert_eq!(
        short, long,
        "allocation count scales with steps: {short} @ K=4 vs {long} @ K=256"
    );

    // --- adaptive stepping: O(1) per solve (the AdaptiveResult.z clone),
    // not O(steps). Asserted through adaptive_ws with a caller-held
    // tableau; the dopri5_ws convenience wrapper additionally rebuilds
    // Tableau::dopri5() per call (~a dozen small one-off allocations), so
    // it is checked for step-count independence rather than a fixed count.
    let opts = AdaptiveOpts::with_tol(1e-4);
    let dp = Tableau::dopri5();
    let mut ws = RkWorkspace::new();
    for _ in 0..2 {
        let _ = adaptive_ws(&field, &z0, (0.0, 1.0), &dp, &opts, &mut ws).unwrap();
    }
    let before = allocs();
    let r = adaptive_ws(&field, &z0, (0.0, 1.0), &dp, &opts, &mut ws).unwrap();
    let adaptive_allocs = allocs() - before;
    assert!(r.accepted >= 1);
    assert!(
        adaptive_allocs <= 2,
        "adaptive_ws allocated {adaptive_allocs} times (want ≤ 2: the result clone)"
    );

    // dopri5_ws wrapper: per-call cost is constant regardless of tolerance-
    // driven step count (loose tol ~few steps vs tight tol ~many steps)
    let _ = dopri5_ws(&field, &z0, (0.0, 1.0), &opts, &mut ws).unwrap();
    let before = allocs();
    let _ = dopri5_ws(&field, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-2), &mut ws).unwrap();
    let loose = allocs() - before;
    let before = allocs();
    let _ = dopri5_ws(&field, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-6), &mut ws).unwrap();
    let tight = allocs() - before;
    assert_eq!(
        loose, tight,
        "dopri5_ws allocation count scales with step count: {loose} vs {tight}"
    );

    // --- pad_batch_into over a warm buffer: exactly 0 allocations ---
    // This is the engine's batch-assembly hot path (each dispatch worker
    // holds one reusable buffer), so a warm steady state must never touch
    // the allocator — `resize` to the same capacity and `copy_from_slice`
    // only. (The full dispatch round still allocates per response —
    // Response output, channel nodes — so this pins exactly the padding
    // step the perf work moved off the heap.)
    use hypersolvers::coordinator::batcher::pad_batch_into;
    let row_a: Vec<f32> = (0..64).map(|i| 0.01 * i as f32).collect();
    let row_b: Vec<f32> = (0..128).map(|i| -0.02 * i as f32).collect();
    let mut pad_buf: Vec<f32> = Vec::new();
    pad_batch_into(&mut pad_buf, [&row_a[..], &row_b[..]], 4, 64); // warm: one grow
    let before = allocs();
    for _ in 0..16 {
        pad_batch_into(&mut pad_buf, [&row_a[..], &row_b[..]], 4, 64);
        std::hint::black_box(pad_buf.as_slice());
    }
    let pad_allocs = allocs() - before;
    assert_eq!(
        pad_allocs, 0,
        "pad_batch_into over a warm buffer allocated {pad_allocs} times in 16 batches"
    );

    // --- request tracing steady state: exactly 0 allocations ---
    // The observability plane rides the same hot path: stage stamping,
    // the solver thread-local, span-ring pushes, slow-table offers (at
    // capacity), warm (task, variant) interning and histogram records
    // must all stay off the allocator, or tracing un-does the perf work
    // the pins above protect.
    use hypersolvers::coordinator::CoordinatorMetrics;
    use hypersolvers::obs::{self, Span, Stage, StageStamps};
    let metrics = CoordinatorMetrics::new();
    let (_, hists) = metrics.stage_key("cnf_a", "euler_k2"); // cold: interns
    let mk_span = |trace: u64| {
        let mut st = StageStamps::default();
        for s in Stage::ALL {
            st.stamp(s);
        }
        st.nfe = 4;
        Span {
            trace,
            id: trace,
            key: 0,
            rows: 1,
            ok: true,
            stamps: st,
        }
    };
    for i in 0..64 {
        metrics.spans.push(mk_span(i)); // warm: fill past capacity wrap
        metrics.slow.offer(mk_span(i)); // warm: table reaches capacity
    }
    let before = allocs();
    for i in 0..16u64 {
        let mut st = StageStamps::default();
        for s in Stage::ALL {
            st.stamp(s);
        }
        obs::solver_stamp(4, 2, 1);
        let (nfe, acc, rej) = obs::take_solver_stamp();
        st.nfe = nfe;
        st.accepted = acc;
        st.rejected = rej;
        let (key, h) = metrics.stage_key("cnf_a", "euler_k2");
        drop(h);
        let span = Span {
            trace: 1000 + i,
            id: 1000 + i,
            key,
            rows: 1,
            ok: true,
            stamps: st,
        };
        hists
            .total
            .record(std::time::Duration::from_micros(st.dur_us(Stage::Submit, Stage::Reply)));
        metrics.spans.push(span);
        metrics.slow.offer(span);
        std::hint::black_box(span.total_us());
    }
    let trace_allocs = allocs() - before;
    assert_eq!(
        trace_allocs, 0,
        "warm tracing path allocated {trace_allocs} times in 16 spans"
    );

    // --- audit sampling decision: exactly 0 allocations ---
    // The shadow-audit sampler sits on the completion path of EVERY
    // request (only sampled ones pay the copy); the decide() call itself
    // is one atomic increment + a splitmix64 mix and must never touch the
    // allocator, at any rate.
    use hypersolvers::obs::audit::AuditSampler;
    let samplers = [
        AuditSampler::new(0.0, 7),
        AuditSampler::new(0.25, 7),
        AuditSampler::new(1.0, 7),
    ];
    for s in &samplers {
        s.decide(); // warm (nothing to warm, but keep windows symmetric)
    }
    let before = allocs();
    let mut sampled = 0u64;
    for s in &samplers {
        for _ in 0..256 {
            if s.decide() {
                sampled += 1;
            }
        }
    }
    std::hint::black_box(sampled);
    let sampler_allocs = allocs() - before;
    assert_eq!(
        sampler_allocs, 0,
        "audit sampling decision allocated {sampler_allocs} times in 768 calls"
    );
}
