//! Backend cross-validation: for every variant in a (synthetic) manifest,
//! the `NativeBackend`'s output must match the solver-level `odeint_*` /
//! `dopri5` call made directly against the loaded weights — the backend adds
//! routing, caching and shape plumbing, never numerics. When real artifacts
//! and a PJRT client are present, the native output must also agree with
//! the `PjrtBackend` within 1e-4; otherwise that half skips with a message.

use hypersolvers::nn::CnfModel;
use hypersolvers::runtime::{
    pjrt_available, BackendKind, ExecBackend, Manifest, NativeBackend,
};
use hypersolvers::solvers::{dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, Tableau};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::fixtures;

#[test]
fn native_backend_matches_solver_level_calls() {
    let dir = fixtures::temp_native_artifacts("xval", &[("cnf_x", 4)]).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let task = m.task("cnf_x").unwrap();
    let model = CnfModel::load(&m.weights_path(task)).unwrap();
    let backend = NativeBackend::new();

    let input: Vec<f32> = (0..8).map(|i| 0.25 * i as f32 - 0.9).collect();
    let z0 = Tensor::new(&[4, 2], input.clone()).unwrap();

    let mut checked = 0;
    for v in &task.variants {
        let direct = if v.solver == "dopri5" {
            dopri5(&model.field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-5))
                .unwrap()
                .z
        } else if v.hyper {
            odeint_hyper(
                &model.field,
                &model.hyper,
                &z0,
                task.s_span,
                v.k,
                &Tableau::by_name(&task.hyper_base).unwrap(),
            )
            .unwrap()
        } else {
            odeint_fixed(
                &model.field,
                &z0,
                task.s_span,
                v.k,
                &Tableau::by_name(&v.solver).unwrap(),
            )
            .unwrap()
        };

        let served = backend.execute(&m, task, v, &input).unwrap();
        assert_eq!(served.z.len(), direct.numel(), "{}", v.name);
        for (i, (a, b)) in served.z.iter().zip(direct.data()).enumerate() {
            assert!(
                (a - b).abs() < 1e-6,
                "{}: element {i} backend {a} vs direct {b}",
                v.name
            );
        }
        checked += 1;
    }
    assert_eq!(checked, 4, "expected the full synthetic variant grid");
}

#[test]
fn native_backend_zero_padding_rows_stay_finite() {
    // the engine zero-pads partial batches; the native solve must produce
    // finite values for those rows too (they're sliced off, but a NaN there
    // would poison shared reductions in other backends)
    let dir = fixtures::temp_native_artifacts("xval_pad", &[("cnf_p", 4)]).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let task = m.task("cnf_p").unwrap();
    let backend = NativeBackend::new();
    let mut input = vec![0.0f32; 8];
    input[0] = 0.7;
    input[1] = -0.3; // one real sample, three zero rows
    for v in &task.variants {
        let out = backend.execute(&m, task, v, &input).unwrap();
        assert!(
            out.z.iter().all(|x| x.is_finite()),
            "{}: padded rows went non-finite",
            v.name
        );
    }
}

#[test]
fn native_matches_pjrt_when_artifacts_present() {
    let m = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP (no artifacts for pjrt comparison): {e}");
            return;
        }
    };
    if !pjrt_available() {
        eprintln!("SKIP: PJRT client unavailable (offline xla stub build)");
        return;
    }
    let pjrt = BackendKind::Pjrt.create().unwrap();
    let native = NativeBackend::new();
    for (name, task) in &m.tasks {
        if task.kind != "cnf" {
            continue; // 2-D states keep the comparison cheap
        }
        let dim: usize = task.state_shape.iter().product();
        let input: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
        for v in &task.variants {
            let a = pjrt.execute(&m, task, v, &input).unwrap();
            let b = native.execute(&m, task, v, &input).unwrap();
            assert_eq!(a.z.len(), b.z.len(), "{name}/{}", v.name);
            if v.solver == "dopri5" {
                continue; // adaptive paths take their own step sequences
            }
            for (i, (x, y)) in a.z.iter().zip(&b.z).enumerate() {
                assert!(
                    (x - y).abs() < 1e-4,
                    "{name}/{}: element {i} pjrt {x} vs native {y}",
                    v.name
                );
            }
        }
    }
}
