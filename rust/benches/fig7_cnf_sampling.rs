//! Figs. 1 & 7 — lightweight CNF density sampling.
//!
//! For each 2-D density: sample the trained CNF with (a) dopri5 (reference),
//! (b) Heun at K=1 (2 NFE — the paper's failure case), (c) HyperHeun at K=1
//! (2 NFE — the paper's headline). Reported per density: terminal MAPE vs
//! dopri5 samples, sample-quality histogram L1 vs the data distribution,
//! wall-clock per batch, and the speedup factor.
//!
//! Paper claim: HyperHeun at 2 NFE reaches dopri5-level sample quality;
//! plain Heun at the same NFE visibly fails; speedup vs dopri5 is large
//! (paper: ~100× on GPU at their batch sizes — shape, not absolute, is the
//! target here).

use hypersolvers::data::densities::{hist_l1, histogram2d};
use hypersolvers::metrics::mape;
use hypersolvers::nn::CnfModel;
use hypersolvers::solvers::{
    dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, Tableau,
};
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::{self, Bench, Table};
use hypersolvers::util::json::{self, Value};

const DENSITIES: [&str; 4] = [
    "cnf_pinwheel",
    "cnf_rings",
    "cnf_checkerboard",
    "cnf_circles",
];

fn main() {
    let m = require_manifest();
    let bench = Bench::with_budget(300);
    println!("Figs. 1 & 7 — CNF sampling at 2 NFE (K=1, batch 256)\n");
    let mut table = Table::new(&[
        "density", "method", "NFE", "MAPE vs dopri5", "hist L1 vs data",
        "ms/batch", "speedup",
    ]);
    let mut rows_json: Vec<Value> = Vec::new();

    for density in DENSITIES {
        let task = m.task(density).unwrap();
        let model = CnfModel::load(&m.weights_path(task)).unwrap();
        let z0 = load_blob(&m, density, "z0");
        let data = load_blob(&m, density, "density_samples");
        let data_hist = histogram2d(&data, 14, 4.0);
        let opts = AdaptiveOpts::with_tol(1e-5);

        let truth = dopri5(&model.field, &z0, task.s_span, &opts).unwrap();
        let t_d5 = bench.run("d5", || {
            let _ = dopri5(&model.field, &z0, task.s_span, &opts).unwrap();
        });
        let heun = odeint_fixed(&model.field, &z0, task.s_span, 1, &Tableau::heun())
            .unwrap();
        let t_heun = bench.run("heun", || {
            let _ = odeint_fixed(&model.field, &z0, task.s_span, 1, &Tableau::heun())
                .unwrap();
        });
        let hyper = odeint_hyper(
            &model.field, &model.hyper, &z0, task.s_span, 1, &Tableau::heun(),
        )
        .unwrap();
        let t_hyper = bench.run("hyperheun", || {
            let _ = odeint_hyper(
                &model.field, &model.hyper, &z0, task.s_span, 1, &Tableau::heun(),
            )
            .unwrap();
        });

        let short = density.strip_prefix("cnf_").unwrap();
        for (name, nfe, samples, t) in [
            ("dopri5", truth.nfe, &truth.z, &t_d5),
            ("heun K=1", 2, &heun, &t_heun),
            ("hyperheun K=1", 2, &hyper, &t_hyper),
        ] {
            let mp = mape(samples, &truth.z).unwrap();
            let hl1 = hist_l1(&histogram2d(samples, 14, 4.0), &data_hist);
            table.row(&[
                short.into(),
                name.into(),
                nfe.to_string(),
                format!("{mp:.4}"),
                format!("{hl1:.3}"),
                format!("{:.3}", t.mean_ms()),
                format!("{:.1}x", t_d5.mean_ms() / t.mean_ms()),
            ]);
            rows_json.push(json::obj(vec![
                ("density", json::s(short)),
                ("method", json::s(name)),
                ("nfe", json::num(nfe as f64)),
                ("mape_vs_dopri5", json::num(mp)),
                ("hist_l1_vs_data", json::num(hl1)),
                ("ms_per_batch", json::num(t.mean_ms())),
                (
                    "speedup_vs_dopri5",
                    json::num(t_d5.mean_ms() / t.mean_ms()),
                ),
            ]));
        }
    }
    table.print();
    println!(
        "\npaper: hypersolved CNF sampling in 2 NFE matches dopri5 quality \
         while Heun at 2 NFE fails"
    );
    let doc = benchkit::bench_doc("fig7_cnf_sampling", vec![("rows", Value::Arr(rows_json))]);
    match benchkit::write_bench_json("BENCH_fig7_cnf.json", &doc) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench JSON: {e}"),
    }

    // Fig. 1 qualitative: side-by-side density renders for one density
    let density = "cnf_pinwheel";
    let task = m.task(density).unwrap();
    let model = CnfModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, density, "z0");
    let truth = dopri5(
        &model.field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-5),
    )
    .unwrap();
    let heun =
        odeint_fixed(&model.field, &z0, task.s_span, 1, &Tableau::heun()).unwrap();
    let hyper = odeint_hyper(
        &model.field, &model.hyper, &z0, task.s_span, 1, &Tableau::heun(),
    )
    .unwrap();
    println!("\nFig. 1 (qualitative) — pinwheel samples:");
    let bins = 12;
    let renders: Vec<(&str, String)> = vec![
        ("dopri5", hypersolvers::data::densities::density_ascii(
            &histogram2d(&truth.z, bins, 4.0), bins)),
        ("heun 2 NFE", hypersolvers::data::densities::density_ascii(
            &histogram2d(&heun, bins, 4.0), bins)),
        ("hyperheun 2 NFE", hypersolvers::data::densities::density_ascii(
            &histogram2d(&hyper, bins, 4.0), bins)),
    ];
    let rows: Vec<Vec<&str>> = renders
        .iter()
        .map(|(_, r)| r.lines().collect())
        .collect();
    println!(
        "{:<26}{:<26}{}",
        renders[0].0, renders[1].0, renders[2].0
    );
    for i in 0..bins {
        println!("{:<26}{:<26}{}", rows[0][i], rows[1][i], rows[2][i]);
    }
}
