//! Fig. 6 — hypersolver generalization across base solvers of the same
//! order.
//!
//! A single HyperMidpoint (trained with α = 0.5 as the base) is evaluated,
//! WITHOUT finetuning, with its base solver swapped across the second-order
//! α family (Fig. 5 right). Series reported: terminal MAPE of every plain
//! α-method vs the same α-method + the HyperMidpoint correction.
//!
//! Paper claim: the hypersolver keeps its advantage across the whole
//! family, degrading gracefully as α moves away from 0.5.

use hypersolvers::metrics::mape;
use hypersolvers::nn::ImageModel;
use hypersolvers::solvers::{odeint_fixed, odeint_hyper, Tableau};
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::Table;

fn main() {
    let m = require_manifest();
    let ds = "img_smnist";
    let task = m.task(ds).unwrap();
    let model = ImageModel::load(&m.weights_path(task)).unwrap();
    let Some(hyper_mid) = &model.hyper_midpoint else {
        eprintln!("weights for {ds} carry no hyper_midpoint net — re-run `make artifacts`");
        return;
    };
    let z0 = load_blob(&m, ds, "z0");
    let truth = load_blob(&m, ds, "truth");
    let k = 4; // fixed step count across the family

    println!(
        "Fig. 6 — HyperMidpoint (trained at alpha=0.5) across the alpha family, K={k}\n"
    );
    let mut table = Table::new(&[
        "alpha", "MAPE alpha-method", "MAPE + HyperMidpoint", "improvement",
    ]);
    for &alpha in &[0.1f32, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0] {
        let tab = Tableau::alpha(alpha).unwrap();
        let plain = odeint_fixed(&model.field, &z0, task.s_span, k, &tab).unwrap();
        let hyper =
            odeint_hyper(&model.field, hyper_mid, &z0, task.s_span, k, &tab).unwrap();
        let m_plain = mape(&plain, &truth).unwrap();
        let m_hyper = mape(&hyper, &truth).unwrap();
        table.row(&[
            format!("{alpha:.1}{}", if alpha == 0.5 { " (midpoint)" } else if alpha == 1.0 { " (heun)" } else { "" }),
            format!("{m_plain:.4}"),
            format!("{m_hyper:.4}"),
            format!("{:.2}x", m_plain / m_hyper),
        ]);
    }
    table.print();
    println!(
        "\n(α=0.5 is the training base; paper: pareto efficiency is preserved \
         over the entire family)"
    );
}
