//! Fig. 9 (appendix) — MAPE–NFE pareto fronts on both image datasets.
//!
//! The same sweep as Fig. 3 but with NFE on the cost axis (the appendix
//! variant). Kept as its own bench so `cargo bench` regenerates every
//! figure one-to-one; the dense K grid here is finer than Fig. 3's.
//! Key numbers are also emitted through the shared benchkit JSON schema
//! (`BENCH_fig9_pareto.json`), with the front extracted by the exact
//! non-dominated-set rule of `pareto::front`.

use hypersolvers::metrics::{mape, ParetoPoint};
use hypersolvers::nn::ImageModel;
use hypersolvers::pareto::front_of;
use hypersolvers::solvers::{odeint_fixed, odeint_hyper, Tableau};
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::{self, Table};
use hypersolvers::util::json::{self, Value};

fn main() {
    let m = require_manifest();
    let mut datasets_json: Vec<Value> = Vec::new();
    for ds in ["img_smnist", "img_scifar"] {
        let task = m.task(ds).unwrap();
        let model = ImageModel::load(&m.weights_path(task)).unwrap();
        let z0 = load_blob(&m, ds, "z0");
        let truth = load_blob(&m, ds, "truth");

        println!("\nFig. 9 — {ds} MAPE vs NFE");
        let mut table = Table::new(&["NFE", "euler", "midpoint", "rk4", "hypereuler"]);
        let mut points = Vec::new();

        // a common NFE grid; for each method pick K so stages*K == NFE
        for nfe in [1usize, 2, 4, 6, 8, 12, 16, 24, 32] {
            let mut row = vec![nfe.to_string()];
            for (tab, hyper) in [
                (Tableau::euler(), false),
                (Tableau::midpoint(), false),
                (Tableau::rk4(), false),
                (Tableau::euler(), true),
            ] {
                let name = if hyper { "hypereuler".to_string() } else { tab.name.clone() };
                let stages = if hyper { 1 } else { tab.stages() };
                if nfe % stages != 0 {
                    row.push("-".into());
                    continue;
                }
                let k = nfe / stages;
                let zt = if hyper {
                    odeint_hyper(&model.field, &model.hyper, &z0, task.s_span, k, &tab)
                        .unwrap()
                } else {
                    odeint_fixed(&model.field, &z0, task.s_span, k, &tab).unwrap()
                };
                let mp = mape(&zt, &truth).unwrap();
                row.push(format!("{mp:.4}"));
                points.push(ParetoPoint {
                    label: format!("{name}_nfe{nfe}"),
                    cost: nfe as f64,
                    error: mp,
                });
            }
            table.row(&row);
        }
        table.print();
        let front_idx = front_of(&points, |p| (p.cost, p.error));
        let front: Vec<&ParetoPoint> = front_idx.iter().map(|&i| &points[i]).collect();
        println!(
            "front: {}",
            front
                .iter()
                .map(|p| p.label.as_str())
                .collect::<Vec<_>>()
                .join(" -> ")
        );
        let low_nfe_hyper = front
            .iter()
            .filter(|p| p.cost <= 8.0 && p.label.starts_with("hypereuler"))
            .count();
        println!(
            "hypereuler holds {low_nfe_hyper} of the front points at NFE<=8 \
             (paper: dominant at low NFE)"
        );
        datasets_json.push(json::obj(vec![
            ("dataset", json::s(ds)),
            (
                "points",
                Value::Arr(
                    points
                        .iter()
                        .map(|p| {
                            json::obj(vec![
                                ("label", json::s(&p.label)),
                                ("nfe", json::num(p.cost)),
                                ("mape", json::num(p.error)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "front",
                Value::Arr(front.iter().map(|p| json::s(&p.label)).collect()),
            ),
            (
                "hyper_front_points_low_nfe",
                json::num(low_nfe_hyper as f64),
            ),
        ]));
    }

    let doc = benchkit::bench_doc("fig9_pareto_nfe", vec![("datasets", Value::Arr(datasets_json))]);
    match benchkit::write_bench_json("BENCH_fig9_pareto.json", &doc) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench JSON: {e}"),
    }
}
