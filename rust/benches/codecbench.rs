//! Codec bench (ours) — the API v1 JSON-line codec against the API v2
//! binary framed codec, encode and decode, on the wide request shape the
//! serving benches use (512 rows × 64 dims by default ⇒ 128 KiB of row
//! data per message).
//!
//! v1 pays a text round trip per float (format on encode, parse on
//! decode, plus a `Vec<Vec<f32>>` of row allocations); v2 writes the rows
//! once as little-endian bytes behind a small JSON header and reads them
//! back straight into the contiguous block the batcher consumes. The
//! decode side is the one the server's hot path cares about — the
//! trajectory gate holds v2 decode strictly above v1.
//!
//! ```bash
//! cargo bench --bench codecbench
//! cargo bench --bench codecbench -- --rows 2048 --dims 16
//! ```
//!
//! Results go to `BENCH_codec.json` (override with `BENCH_JSON`) and the
//! headline ratios are appended to the rolling `BENCH_trajectory.json`.

use hypersolvers::api::v1::{InferRequest, InferResponse};
use hypersolvers::api::{v1, v2};
use hypersolvers::util::benchkit::{self, Bench, Measurement, Table};
use hypersolvers::util::cli::Cli;
use hypersolvers::util::json::{self, Value};
use hypersolvers::util::prng::Rng;

fn main() {
    let args = Cli::new("codecbench — v1 JSON lines vs v2 binary frames")
        .opt("rows", "512", "rows per request")
        .opt("dims", "64", "values per row")
        .opt("measure-ms", "400", "wall-clock budget per measurement")
        .parse_env();
    let rows = args.get_usize("rows").max(1);
    let dims = args.get_usize("dims").max(1);
    let payload_bytes = rows * dims * 4;

    let mut rng = Rng::new(21);
    let input: Vec<f32> = (0..rows * dims).map(|_| rng.normal_f32()).collect();
    let mut req = InferRequest::batch("cnf_wide", 0.5, rows, input);
    req.id = Some(1);
    req.deadline_us = Some(250_000);
    let resp = InferResponse {
        id: 1,
        variant: "euler_k2".into(),
        mape: 0.25,
        nfe: 2,
        latency_us: 900,
        batch_fill: 1.0,
        samples: rows,
        dims,
        output: (0..rows * dims).map(|_| rng.normal_f32()).collect(),
    };

    // pre-encoded messages for the decode measurements
    let v1_line = json::to_string(&v1::encode_request(&req));
    let v2_frame = v2::encode_request(&req);
    let v1_resp_line = json::to_string(&v1::encode_response(&resp, 1));
    let v2_resp_frame = v2::encode_response(&resp);
    println!(
        "rows={rows} dims={dims}  payload {payload_bytes} B  \
         v1 line {} B  v2 frame {} B",
        v1_line.len(),
        v2_frame.len()
    );

    let b = Bench::with_budget(args.get_usize("measure-ms") as u64);

    let enc_v1 = b.run("encode v1", || {
        std::hint::black_box(json::to_string(&v1::encode_request(&req)));
    });
    let enc_v2 = b.run("encode v2", || {
        std::hint::black_box(v2::encode_request(&req));
    });
    let dec_v1 = b.run("decode v1", || {
        let v = json::parse(&v1_line).unwrap();
        let (r, _) = v1::decode_request(&v).unwrap();
        std::hint::black_box(r);
    });
    let dec_v2 = b.run("decode v2", || {
        let frame = v2::read_frame(&mut &v2_frame[..]).unwrap();
        std::hint::black_box(v2::decode_request(frame).unwrap());
    });
    let dec_resp_v1 = b.run("decode v1 response", || {
        let v = json::parse(&v1_resp_line).unwrap();
        std::hint::black_box(v1::decode_reply(&v).unwrap());
    });
    let dec_resp_v2 = b.run("decode v2 response", || {
        let frame = v2::read_frame(&mut &v2_resp_frame[..]).unwrap();
        std::hint::black_box(v2::decode_reply(frame).unwrap());
    });

    // MB/s over the *row payload*: both codecs move the same rows·dims·4
    // bytes of f32 data, so this is the apples-to-apples rate (v1's actual
    // wire bytes are larger — the text expansion is part of its cost)
    let mbps = |m: &Measurement| payload_bytes as f64 / (1024.0 * 1024.0) / m.mean.as_secs_f64();
    let us_per_row = |m: &Measurement| m.mean_us() / rows as f64;

    let mut table = Table::new(&["op", "mean µs", "µs/row", "payload MB/s"]);
    for m in [&enc_v1, &enc_v2, &dec_v1, &dec_v2, &dec_resp_v1, &dec_resp_v2] {
        table.row(&[
            m.name.clone(),
            format!("{:.1}", m.mean_us()),
            format!("{:.3}", us_per_row(m)),
            format!("{:.1}", mbps(m)),
        ]);
    }
    table.print();
    println!(
        "\ndecode speedup v2/v1: requests ×{:.1}, responses ×{:.1}",
        dec_v1.mean.as_secs_f64() / dec_v2.mean.as_secs_f64(),
        dec_resp_v1.mean.as_secs_f64() / dec_resp_v2.mean.as_secs_f64()
    );

    let m_json = |m: &Measurement| {
        json::obj(vec![
            ("op", json::s(&m.name)),
            ("mean_us", json::num(m.mean_us())),
            ("us_per_row", json::num(us_per_row(m))),
            ("payload_mb_per_s", json::num(mbps(m))),
            ("iters", json::num(m.iters as f64)),
        ])
    };
    let doc = benchkit::bench_doc(
        "codecbench",
        vec![
            ("rows", json::num(rows as f64)),
            ("dims", json::num(dims as f64)),
            ("payload_bytes", json::num(payload_bytes as f64)),
            ("v1_line_bytes", json::num(v1_line.len() as f64)),
            ("v2_frame_bytes", json::num(v2_frame.len() as f64)),
            (
                "ops",
                Value::Arr(
                    [&enc_v1, &enc_v2, &dec_v1, &dec_v2, &dec_resp_v1, &dec_resp_v2]
                        .into_iter()
                        .map(m_json)
                        .collect(),
                ),
            ),
        ],
    );
    match benchkit::write_bench_json("BENCH_codec.json", &doc) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench JSON: {e}"),
    }

    let entry = benchkit::bench_doc(
        "codecbench",
        vec![
            ("rows", json::num(rows as f64)),
            ("dims", json::num(dims as f64)),
            ("v1_decode_mbps", json::num(mbps(&dec_v1))),
            ("v2_decode_mbps", json::num(mbps(&dec_v2))),
            (
                "v2_over_v1_decode",
                json::num(dec_v1.mean.as_secs_f64() / dec_v2.mean.as_secs_f64()),
            ),
        ],
    );
    match benchkit::append_trajectory(entry) {
        Ok(path) => println!("appended to {}", path.display()),
        Err(e) => eprintln!("failed to append bench trajectory: {e}"),
    }
}
