//! Fig. 2 — asymptotic complexity table.
//!
//! Regenerates the paper's complexity comparison empirically: for each
//! solver, NFE per K steps and the fitted local-truncation-error order
//! (slope of log error vs log ε) on the trained CNF field; for the
//! hypersolver, the δ·ε^{p+1} scaling of Theorem 1 — its one-step error
//! should sit roughly a factor δ below the base method's.
//!
//! Paper rows:  p-th order solver  O(pK) NFE, O(ε^{p+1}) local error;
//!              p-th order hypersolver  O(pK)+K·g, O(δ ε^{p+1}).

use hypersolvers::metrics::mean_l2;
use hypersolvers::nn::CnfModel;
use hypersolvers::solvers::{dopri5, hyper_step, odeint_fixed, AdaptiveOpts, Tableau};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::{fmt_sci, Table};

fn main() {
    let m = require_manifest();
    let task = m.task("cnf_rings").unwrap();
    let model = CnfModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "cnf_rings", "z0");

    println!("Fig. 2 — NFE and local-error order (trained CNF field, rings)\n");
    let mut table = Table::new(&[
        "method", "NFE(K)", "local err eps=1/4", "local err eps=1/8",
        "emp. order", "paper",
    ]);

    // exact one-step references from tight dopri5
    let step_truth = |z: &Tensor, s0: f32, eps: f32| -> Tensor {
        dopri5(&model.field, z, (s0, s0 + eps), &AdaptiveOpts::with_tol(1e-7))
            .unwrap()
            .z
    };

    let solvers = [
        (Tableau::euler(), "O(eps^2)"),
        (Tableau::midpoint(), "O(eps^3)"),
        (Tableau::heun(), "O(eps^3)"),
        (Tableau::rk4(), "O(eps^5)"),
    ];
    for (tab, paper) in &solvers {
        let mut errs = Vec::new();
        for eps in [0.25f32, 0.125] {
            let truth = step_truth(&z0, 0.0, eps);
            let one = odeint_fixed(&model.field, &z0, (0.0, eps), 1, tab).unwrap();
            errs.push(mean_l2(&one, &truth).unwrap());
        }
        let order = (errs[0] / errs[1]).log2();
        table.row(&[
            tab.name.clone(),
            format!("{}K", tab.stages()),
            fmt_sci(errs[0]),
            fmt_sci(errs[1]),
            format!("{order:.2}"),
            paper.to_string(),
        ]);
    }

    // hypersolved heun: local error ≈ δ · (heun local error scale)
    let tab = Tableau::heun();
    let mut errs = Vec::new();
    for eps in [0.25f32, 0.125] {
        let truth = step_truth(&z0, 0.0, eps);
        let one = hyper_step(&model.field, &model.hyper, &tab, 0.0, &z0, eps).unwrap();
        errs.push(mean_l2(&one, &truth).unwrap());
    }
    let order = (errs[0] / errs[1]).log2();
    table.row(&[
        "hyperheun".into(),
        "2K+g".into(),
        fmt_sci(errs[0]),
        fmt_sci(errs[1]),
        format!("{order:.2}"),
        "O(d.eps^3)".into(),
    ]);

    // adaptive row: NFE has no fixed bound; report measured
    let r = dopri5(&model.field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-5)).unwrap();
    table.row(&[
        "dopri5(1e-5)".into(),
        format!("{} (measured)", r.nfe),
        "-".into(),
        "-".into(),
        "-".into(),
        "adaptive".into(),
    ]);

    table.print();
    println!(
        "\nhypersolver residual fit delta = {:.4} (manifest); \
         relative overhead O_r = 1 + MAC_g/(p*MAC_f) = {:.3}",
        task.delta,
        hypersolvers::metrics::relative_overhead(task.mac_f, task.mac_g, 2),
    );
}
