//! Serving bench (ours) — the coordinator under a Poisson workload, plus
//! pipelined-client scenarios over the real TCP/API-v1 surface.
//!
//! This is the deployment story the paper's introduction motivates: tight
//! inference-time constraints. A Poisson trace of CNF sampling requests with
//! a mixed budget profile is replayed against the engine; reported:
//! throughput, latency percentiles, batch fill, NFE spent per request, and
//! the worker-pool concurrency peak (with per-queue affinity, every
//! concurrent batch belongs to a distinct (task, variant) queue). The
//! pipelined scenarios then drive a single TCP connection with a window of
//! in-flight v1 requests (single- and full-batch multi-sample), matching
//! out-of-order completions by id — the serving path external callers
//! actually see.
//!
//! ```bash
//! cargo bench --bench serving_throughput -- --backend native --workers 4
//! cargo bench --bench serving_throughput -- --backend pjrt
//! ```
//!
//! With `--backend native` the bench runs anywhere: if no artifacts exist,
//! a synthetic two-task native fixture set is written to a temp dir.
//!
//! Besides the human-readable tables, the run is summarized to
//! `BENCH_serving.json` (override the path with `BENCH_JSON`): per
//! scenario p50/p95/p99 latency, achieved throughput, batch fill, NFE/req,
//! the worker-pool concurrency peak, and the engine-side stage breakdown
//! (`stage_{queue,pad,exec,total}_{p50,p99}_ms`, from the request spans) —
//! machine-readable so successive PRs can diff serving performance. With
//! `--metrics-addr HOST:PORT` the run also exposes live Prometheus text
//! for whichever engine is currently under load (what CI scrapes).
//!
//! The numerical-health scenarios replay one workload audit-off, audit-on
//! (`--audit-rate 1`-equivalent) and audit-on with inputs shifted far off
//! the training distribution; the off/on p50 pair feeds benchgate's
//! audit-overhead bound, and `--health-prom PATH` writes the audit-enabled
//! exposition for `benchgate --expo-check-health`.
//!
//! The cluster scenarios front a `LocalCluster` of engine nodes with the
//! consistent-hash router (the `hyperrouter` data path in-process): a
//! steady pipelined run reporting aggregate latency plus the router's
//! merged per-node metrics, then a kill-one-node-mid-run pair — retries
//! off vs the failover budget on — whose goodputs land in the bench
//! trajectory for benchgate's resilience rule (retries-on must strictly
//! beat retries-off).

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use hypersolvers::api::v1::{InferReply, InferRequest};
use hypersolvers::coordinator::{
    server, Engine, EngineConfig, Policy, Priority, SloConfig, SubmitOptions,
};
use hypersolvers::data::workload::WorkloadSpec;
use hypersolvers::router::{Ring, Router, RouterConfig};
use hypersolvers::runtime::{BackendKind, Manifest};
use hypersolvers::tensor;
use hypersolvers::util::artifacts::require_manifest;
use hypersolvers::util::benchkit::{self, Table};
use hypersolvers::util::cli::Cli;
use hypersolvers::util::cluster::LocalCluster;
use hypersolvers::util::fixtures;
use hypersolvers::util::json::{self, Value};
use hypersolvers::util::prng::Rng;
use hypersolvers::util::stats;
use hypersolvers::util::threadpool::ThreadPool;

fn main() {
    let args = Cli::new("serving_throughput — coordinator under Poisson load")
        .opt("backend", "native", "execution backend: native | pjrt")
        .opt("workers", "0", "dispatch workers (0 = auto)")
        .opt("requests", "2000", "requests per scenario")
        .opt("rate", "2000", "offered requests/second")
        .opt(
            "pipeline-requests",
            "600",
            "requests per pipelined TCP scenario",
        )
        .opt(
            "pipeline-window",
            "32",
            "in-flight requests on the pipelined connection",
        )
        .opt(
            "matmul-threads",
            "0",
            "when > 0, rerun every scenario with the row-block matmul pool at \
             this size and emit paired off/on rows",
        )
        .opt(
            "wide-requests",
            "200",
            "requests per wide v1-vs-v2 pipelined scenario (native only; \
             0 disables)",
        )
        .opt("wide-rows", "512", "rows per request in the wide scenario")
        .opt("wide-dims", "64", "state dimension of the wide scenario task")
        .opt(
            "overload-factor",
            "3",
            "open-loop overload scenario: offered rate as a multiple of \
             measured capacity (native backend only; 0 disables)",
        )
        .opt(
            "overload-deadline-ms",
            "200",
            "per-request deadline of the overload scenario",
        )
        .opt(
            "overload-secs",
            "1",
            "offered-load duration of each overload run",
        )
        .opt(
            "audit-requests",
            "400",
            "requests per shadow-audit A/B run and per drift-shifted run \
             (0 disables the numerical-health scenarios)",
        )
        .opt(
            "cluster-nodes",
            "3",
            "engine nodes behind the router in the cluster scenarios \
             (native backend only; 0 disables)",
        )
        .opt(
            "cluster-requests",
            "400",
            "requests per cluster scenario run",
        )
        .opt(
            "health-prom",
            "",
            "write the audit-enabled engine's Prometheus exposition to this \
             path after the shifted scenario (what CI gates with \
             `benchgate --expo-check-health`; empty = off)",
        )
        .opt(
            "metrics-addr",
            "",
            "Prometheus exposition listen address, scraping whichever \
             engine is currently under load (empty = off)",
        )
        .parse_env();

    let backend = match BackendKind::from_name(&args.get("backend")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };

    // artifacts: pjrt needs the real export; native falls back to a
    // synthetic fixture set so the bench runs on any machine
    let manifest = match backend {
        BackendKind::Pjrt => require_manifest(),
        BackendKind::Native => match Manifest::load_default() {
            Ok(m) => m,
            Err(_) => {
                eprintln!("no artifacts found — writing a synthetic native fixture set");
                let dir =
                    fixtures::temp_native_artifacts("bench", &[("cnf_a", 16), ("cnf_b", 16)])
                        .expect("write fixtures");
                Manifest::load(&dir).expect("fixture manifest")
            }
        },
    };
    let artifacts_dir = manifest.dir.clone();
    // ≥2 distinct tasks when available → distinct queues overlap on the pool
    let tasks: Vec<String> = manifest
        .tasks
        .iter()
        .filter(|(_, t)| t.kind == "cnf")
        .map(|(k, _)| k.clone())
        .take(2)
        .collect();
    assert!(!tasks.is_empty(), "no cnf tasks in manifest");
    let dims: Vec<usize> = tasks
        .iter()
        .map(|t| manifest.task(t).unwrap().state_shape[1..].iter().product())
        .collect();
    let caps: Vec<usize> = tasks
        .iter()
        .map(|t| manifest.task(t).unwrap().batch())
        .collect();

    println!(
        "backend={backend}  tasks={tasks:?}  requests={} rate={}",
        args.get_usize("requests"),
        args.get_f64("rate")
    );

    let mut table = Table::new(&[
        "scenario", "mm", "reqs", "offered rps", "achieved rps", "p50 ms",
        "p99 ms", "fill", "NFE/req", "conc peak",
    ]);
    let mut scenarios_json: Vec<Value> = Vec::new();
    let mut resolved_workers = 0usize;
    let mut headline: Option<(f64, f64)> = None; // mixed-budget (p50, rps), pool off
    let mut headline_stages: Option<Vec<(&'static str, Value)>> = None;

    // Optional live exposition plane: scenarios rotate through short-lived
    // engines, so the scrape renders whichever one is currently registered
    // (CI scrapes this mid-run and gates it with `benchgate --expo-check`).
    let metrics_engine: Arc<Mutex<Option<Arc<Engine>>>> = Arc::new(Mutex::new(None));
    let metrics_addr = args.get("metrics-addr");
    if !metrics_addr.is_empty() {
        let listener = TcpListener::bind(metrics_addr.as_str()).expect("bind --metrics-addr");
        println!("metrics exposition on {}", listener.local_addr().unwrap());
        let current = Arc::clone(&metrics_engine);
        std::thread::spawn(move || {
            let _ = server::serve_metrics_with(listener, move || {
                match current.lock().unwrap().as_ref() {
                    Some(e) => e.render_prometheus(),
                    // before the first scenario registers: minimal but
                    // parseable, so early scrapes see text, not a reset
                    None => "# TYPE hypersolvers_up gauge\nhypersolvers_up 1\n".into(),
                }
            });
        });
    }
    let register = |e: &Arc<Engine>| {
        *metrics_engine.lock().unwrap() = Some(Arc::clone(e));
    };

    let engine_config = |workers: usize| EngineConfig {
        artifacts_dir: artifacts_dir.clone(),
        max_wait: Duration::from_millis(2),
        policy: Policy::MinMacs,
        backend,
        workers,
        ..Default::default()
    };

    // paired matmul-pool modes: 0 (off) always, plus --matmul-threads on.
    // Only the native backend runs batches through tensor::gemm_into —
    // pairing a PJRT run would double the bench to measure pure noise.
    let mm = args.get_usize("matmul-threads");
    let pool_modes: Vec<usize> = if mm > 0 && matches!(backend, BackendKind::Native) {
        vec![0, mm]
    } else {
        if mm > 0 {
            eprintln!(
                "--matmul-threads ignored: the {backend} backend never reaches \
                 the row-block matmul pool"
            );
        }
        vec![0]
    };

    let scenario_defs = [
        ("mixed budgets", vec![(0.05f32, 0.6f64), (0.15, 0.3), (0.01, 0.1)]),
        ("tight only (dopri5-ish)", vec![(0.0005, 1.0)]),
        ("loose only", vec![(0.3, 1.0)]),
    ];
    let mut runs: Vec<(&str, &Vec<(f32, f64)>, usize)> = Vec::new();
    for (s, b) in &scenario_defs {
        for &m in &pool_modes {
            runs.push((*s, b, m));
        }
    }

    for (scenario, budgets, mode) in runs {
        if mode > 0 {
            tensor::set_matmul_pool(Arc::new(ThreadPool::new(mode)));
        } else {
            tensor::clear_matmul_pool();
        }
        let engine = Arc::new(Engine::new(engine_config(args.get_usize("workers"))).unwrap());
        register(&engine);
        resolved_workers = engine.worker_count();
        for t in &tasks {
            engine.warmup(t).unwrap();
        }

        let spec = WorkloadSpec {
            rate: args.get_f64("rate"),
            count: args.get_usize("requests"),
            tasks: tasks.clone(),
            budgets: budgets.clone(),
        };
        let trace = spec.generate(&mut Rng::new(7));
        let mut rng = Rng::new(8);

        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(trace.events.len());
        for ev in &trace.events {
            // replay arrival times; sleep for long gaps, yield for short
            // ones — busy-spinning starves the dispatchers on few cores
            let target = t0 + Duration::from_secs_f64(ev.at_s);
            loop {
                let now = Instant::now();
                if now >= target {
                    break;
                }
                let gap = target - now;
                if gap > Duration::from_millis(1) {
                    std::thread::sleep(gap - Duration::from_micros(500));
                } else {
                    std::thread::yield_now();
                }
            }
            let dim = dims[tasks.iter().position(|t| *t == ev.task).unwrap()];
            let input: Vec<f32> = (0..dim).map(|_| rng.normal_f32()).collect();
            pending.push(engine.submit(&ev.task, ev.budget, input).unwrap());
        }
        let mut latencies = Vec::with_capacity(pending.len());
        for handle in pending {
            let resp = handle.wait().unwrap();
            latencies.push(resp.latency.as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        let metrics = engine.metrics();
        let nfe_per_req = metrics.nfe_total.load(Relaxed) as f64
            / metrics.responses.load(Relaxed) as f64;
        let conc_peak = metrics.inflight_peak.load(Relaxed);
        let achieved_rps = trace.events.len() as f64 / wall;
        let (p50, p95, p99) = (
            stats::percentile(&latencies, 50.0),
            stats::percentile(&latencies, 95.0),
            stats::percentile(&latencies, 99.0),
        );
        table.row(&[
            scenario.into(),
            mode.to_string(),
            trace.events.len().to_string(),
            format!("{:.0}", spec.rate),
            format!("{achieved_rps:.0}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            format!("{:.2}", metrics.fill_ratio()),
            format!("{nfe_per_req:.1}"),
            conc_peak.to_string(),
        ]);
        let mut row = vec![
            ("scenario", json::s(scenario)),
            ("mode", json::s("inproc_poisson")),
            ("matmul_threads", json::num(mode as f64)),
            ("requests", json::num(trace.events.len() as f64)),
            ("offered_rps", json::num(spec.rate)),
            ("throughput_rps", json::num(achieved_rps)),
            ("p50_ms", json::num(p50)),
            ("p95_ms", json::num(p95)),
            ("p99_ms", json::num(p99)),
            ("fill", json::num(metrics.fill_ratio())),
            ("nfe_per_req", json::num(nfe_per_req)),
            ("inflight_peak", json::num(conc_peak as f64)),
        ];
        row.extend(stage_fields(metrics));
        scenarios_json.push(json::obj(row));
        if scenario == "mixed budgets" && mode == 0 {
            headline = Some((p50, achieved_rps));
            headline_stages = Some(stage_fields(metrics));
        }
        println!("[{scenario}] mm={mode} {}", metrics.report());
        if conc_peak >= 2 {
            match backend {
                BackendKind::Native => println!(
                    "[{scenario}] {conc_peak} batches from distinct (task, variant) \
                     queues executed concurrently on the worker pool"
                ),
                BackendKind::Pjrt => println!(
                    "[{scenario}] {conc_peak} batches from distinct (task, variant) \
                     queues overlapped on the worker pool (pipelined into the \
                     serial PJRT executor thread)"
                ),
            }
        }
    }
    tensor::clear_matmul_pool();

    // ---- pipelined TCP scenarios: the API v1 surface over a socket ----
    //
    // One connection, `window` requests in flight, completions matched by
    // id (possibly out of order). ×1 sends classic single-sample requests;
    // ×B sends full-batch multi-sample requests (each fills an executable
    // batch by itself — the high-throughput client shape).
    let pip_requests = args.get_usize("pipeline-requests");
    let window = args.get_usize("pipeline-window").max(1);
    for &full_batch in &[false, true] {
        let samples_label = if full_batch { "×B" } else { "×1" };
        let scenario = format!("pipelined tcp {samples_label}");
        let engine = Arc::new(Engine::new(engine_config(args.get_usize("workers"))).unwrap());
        register(&engine);
        for t in &tasks {
            engine.warmup(t).unwrap();
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        {
            let engine = Arc::clone(&engine);
            std::thread::spawn(move || {
                let _ = server::serve_listener(engine, listener);
            });
        }
        let mut client = server::Client::connect(&addr).unwrap();

        let mut rng = Rng::new(9);
        let make_req = |i: usize, rng: &mut Rng| -> InferRequest {
            let ti = i % tasks.len();
            let samples = if full_batch { caps[ti] } else { 1 };
            let dim = dims[ti];
            let input: Vec<f32> = (0..samples * dim).map(|_| rng.normal_f32()).collect();
            InferRequest::batch(&tasks[ti], 0.05, samples, input)
        };

        let t0 = Instant::now();
        let mut sent_at: HashMap<u64, Instant> = HashMap::with_capacity(window);
        let mut latencies: Vec<f64> = Vec::with_capacity(pip_requests);
        let mut rows_done = 0usize;
        let mut next = 0usize;
        while next < pip_requests.min(window) {
            let id = client.send(&make_req(next, &mut rng)).unwrap();
            sent_at.insert(id, Instant::now());
            next += 1;
        }
        while latencies.len() < pip_requests {
            let reply = client.recv_reply().unwrap();
            let id = reply.id().expect("reply without id");
            let at = sent_at.remove(&id).expect("unmatched reply id");
            latencies.push(at.elapsed().as_secs_f64() * 1e3);
            match reply {
                InferReply::Ok(r) => rows_done += r.samples,
                InferReply::Err(e) => panic!("pipelined request failed: {}", e.error),
            }
            if next < pip_requests {
                let id = client.send(&make_req(next, &mut rng)).unwrap();
                sent_at.insert(id, Instant::now());
                next += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        assert!(sent_at.is_empty(), "unanswered ids: {}", sent_at.len());

        let metrics = engine.metrics();
        let nfe_per_req = metrics.nfe_total.load(Relaxed) as f64
            / metrics.responses.load(Relaxed) as f64;
        let conc_peak = metrics.inflight_peak.load(Relaxed);
        let achieved_rps = pip_requests as f64 / wall;
        let (p50, p95, p99) = (
            stats::percentile(&latencies, 50.0),
            stats::percentile(&latencies, 95.0),
            stats::percentile(&latencies, 99.0),
        );
        table.row(&[
            scenario.clone(),
            "0".into(),
            pip_requests.to_string(),
            "-".into(),
            format!("{achieved_rps:.0}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            format!("{:.2}", metrics.fill_ratio()),
            format!("{nfe_per_req:.1}"),
            conc_peak.to_string(),
        ]);
        let mut row = vec![
            ("scenario", json::s(&scenario)),
            ("mode", json::s("tcp_pipelined")),
            ("matmul_threads", json::num(0.0)),
            ("requests", json::num(pip_requests as f64)),
            ("window", json::num(window as f64)),
            // aligned with the envelope's "tasks" array — requests
            // alternate tasks, and ×B uses each task's own batch cap
            (
                "samples_per_req_by_task",
                Value::Arr(
                    caps.iter()
                        .map(|&c| json::num(if full_batch { c as f64 } else { 1.0 }))
                        .collect(),
                ),
            ),
            ("rows", json::num(rows_done as f64)),
            ("throughput_rps", json::num(achieved_rps)),
            ("throughput_rows_per_s", json::num(rows_done as f64 / wall)),
            ("p50_ms", json::num(p50)),
            ("p95_ms", json::num(p95)),
            ("p99_ms", json::num(p99)),
            ("fill", json::num(metrics.fill_ratio())),
            ("nfe_per_req", json::num(nfe_per_req)),
            ("inflight_peak", json::num(conc_peak as f64)),
        ];
        row.extend(stage_fields(metrics));
        scenarios_json.push(json::obj(row));
        println!(
            "[{scenario}] window={window} rows={rows_done} {}",
            metrics.report()
        );
    }

    // ---- wide pipelined TCP: v1 JSON lines vs v2 binary frames ----
    //
    // The codec A/B the wire-protocol work is judged on. One synthetic
    // wide task ([rows × dims] per request, 512×64 by default ⇒ 128 KiB of
    // row data per request) with a deliberately cheap euler_k2 variant, so
    // end-to-end latency is dominated by the wire path: encode, socket,
    // decode, batch assembly. Same engine shape, same workload, same
    // window — the only difference between the paired runs is the dialect
    // the client negotiates.
    let wide_requests = args.get_usize("wide-requests");
    let mut wide_headline: Option<(f64, f64)> = None; // (v1 p50, v2 p50)
    if wide_requests > 0 && matches!(backend, BackendKind::Native) {
        let wide_task = "cnf_wide";
        let wide_rows = args.get_usize("wide-rows").max(1);
        let wide_dims = args.get_usize("wide-dims").max(1);
        let wide_dir =
            fixtures::temp_wide_native_artifacts("bench_wide", wide_task, wide_rows, wide_dims)
                .expect("write wide fixtures");
        let mut wide_pair = (0.0f64, 0.0f64);
        for &use_v2 in &[false, true] {
            let dialect = if use_v2 { "v2" } else { "v1" };
            let scenario = format!("pipelined wide {dialect}");
            let engine = Arc::new(
                Engine::new(EngineConfig {
                    artifacts_dir: wide_dir.clone(),
                    max_wait: Duration::from_millis(2),
                    policy: Policy::MinMacs,
                    backend,
                    workers: args.get_usize("workers"),
                    ..Default::default()
                })
                .unwrap(),
            );
            register(&engine);
            engine.warmup(wide_task).unwrap();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            {
                let engine = Arc::clone(&engine);
                std::thread::spawn(move || {
                    let _ = server::serve_listener(engine, listener);
                });
            }
            let mut client = server::Client::connect(&addr).unwrap();
            if use_v2 {
                assert!(client.prefer_v2().unwrap(), "server must offer v2");
            }

            let mut rng = Rng::new(13);
            let t0 = Instant::now();
            let mut sent_at: HashMap<u64, Instant> = HashMap::with_capacity(window);
            let mut latencies: Vec<f64> = Vec::with_capacity(wide_requests);
            let mut rows_done = 0usize;
            let mut next = 0usize;
            let send_one = |client: &mut server::Client,
                                sent_at: &mut HashMap<u64, Instant>,
                                rng: &mut Rng| {
                let input: Vec<f32> =
                    (0..wide_rows * wide_dims).map(|_| rng.normal_f32()).collect();
                let req = InferRequest::batch(wide_task, 0.5, wide_rows, input);
                let id = client.send(&req).unwrap();
                sent_at.insert(id, Instant::now());
            };
            while next < wide_requests.min(window) {
                send_one(&mut client, &mut sent_at, &mut rng);
                next += 1;
            }
            while latencies.len() < wide_requests {
                let reply = client.recv_reply().unwrap();
                let id = reply.id().expect("reply without id");
                let at = sent_at.remove(&id).expect("unmatched reply id");
                latencies.push(at.elapsed().as_secs_f64() * 1e3);
                match reply {
                    InferReply::Ok(r) => rows_done += r.samples,
                    InferReply::Err(e) => panic!("wide request failed: {}", e.error),
                }
                if next < wide_requests {
                    send_one(&mut client, &mut sent_at, &mut rng);
                    next += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            assert!(sent_at.is_empty(), "unanswered ids: {}", sent_at.len());

            let achieved_rps = wide_requests as f64 / wall;
            // request + response rows both cross the wire; count one side
            let wire_mb_s = (rows_done * wide_dims * 4) as f64 / (1024.0 * 1024.0) / wall;
            let (p50, p95, p99) = (
                stats::percentile(&latencies, 50.0),
                stats::percentile(&latencies, 95.0),
                stats::percentile(&latencies, 99.0),
            );
            if use_v2 {
                wide_pair.1 = p50;
            } else {
                wide_pair.0 = p50;
            }
            let metrics = engine.metrics();
            table.row(&[
                scenario.clone(),
                "0".into(),
                wide_requests.to_string(),
                "-".into(),
                format!("{achieved_rps:.0}"),
                format!("{p50:.2}"),
                format!("{p99:.2}"),
                format!("{:.2}", metrics.fill_ratio()),
                "-".into(),
                metrics.inflight_peak.load(Relaxed).to_string(),
            ]);
            let mut row = vec![
                ("scenario", json::s(&scenario)),
                (
                    "mode",
                    json::s(if use_v2 { "tcp_pipelined_v2" } else { "tcp_pipelined" }),
                ),
                ("task", json::s(wide_task)),
                ("requests", json::num(wide_requests as f64)),
                ("window", json::num(window as f64)),
                ("rows_per_req", json::num(wide_rows as f64)),
                ("dims", json::num(wide_dims as f64)),
                ("rows", json::num(rows_done as f64)),
                ("throughput_rps", json::num(achieved_rps)),
                ("throughput_rows_per_s", json::num(rows_done as f64 / wall)),
                ("payload_mb_per_s", json::num(wire_mb_s)),
                ("p50_ms", json::num(p50)),
                ("p95_ms", json::num(p95)),
                ("p99_ms", json::num(p99)),
            ];
            row.extend(stage_fields(metrics));
            scenarios_json.push(json::obj(row));
            println!(
                "[{scenario}] window={window} rows={rows_done} \
                 payload {wire_mb_s:.1} MB/s"
            );
        }
        println!(
            "\n[wide] {wide_rows}×{wide_dims} pipelined p50: v1 {:.2} ms vs v2 {:.2} ms",
            wide_pair.0, wide_pair.1
        );
        wide_headline = Some(wide_pair);
    } else if wide_requests > 0 {
        println!(
            "\n[wide] skipped: the v1-vs-v2 scenario needs the native \
             backend's synthetic wide fixture"
        );
    }

    // ---- open-loop overload: SLO admission control + shedding ----
    //
    // A heavy synthetic task (128-wide MLP field, dopri5-pinned) gives the
    // engine a finite capacity; the scenario then *offers* a multiple of it
    // open-loop — requests keep arriving whether or not earlier ones
    // finished, the regime where closed-loop benches can't see overload.
    // Run once with every SLO defence off (baseline) and once with
    // admission + shedding on; goodput = deadline-met completions over all
    // submitted requests, rejected/shed ones counted as failed. Shedding
    // must *raise* goodput: the baseline burns capacity on rows that are
    // already dead on arrival.
    let overload_factor = args.get_f64("overload-factor");
    let mut overload_headline: Option<(f64, f64)> = None; // (shed-on, shed-off)
    if overload_factor > 0.0 && matches!(backend, BackendKind::Native) {
        let deadline = Duration::from_millis(args.get_usize("overload-deadline-ms") as u64);
        let offer_secs = args.get_f64("overload-secs").max(0.1);
        let heavy_task = "cnf_heavy";
        let heavy_dir = fixtures::temp_heavy_native_artifacts("bench_overload", heavy_task, 16)
            .expect("write heavy fixtures");
        let heavy_manifest = Manifest::load(&heavy_dir).expect("heavy manifest");
        let b_cap = heavy_manifest.task(heavy_task).unwrap().batch();
        let heavy_config = |slo: SloConfig| EngineConfig {
            artifacts_dir: heavy_dir.clone(),
            max_wait: Duration::from_millis(2),
            policy: Policy::MinMacs,
            backend,
            workers: args.get_usize("workers"),
            slo,
            ..Default::default()
        };
        let dopri = |deadline: Option<Duration>, priority: Priority| SubmitOptions {
            variant: Some("dopri5".into()),
            deadline,
            priority,
            ..Default::default()
        };

        // capacity: sequential full-batch submissions on a warm engine;
        // the first (cold) batch is excluded
        let engine = Engine::new(heavy_config(SloConfig::default())).unwrap();
        engine.warmup(heavy_task).unwrap();
        let mut rng = Rng::new(11);
        let mut walls = Vec::new();
        for _ in 0..6 {
            let input: Vec<f32> = (0..b_cap * 2).map(|_| rng.normal_f32()).collect();
            let t0 = Instant::now();
            engine
                .submit_opts(heavy_task, 0.5, input, b_cap, &dopri(None, Priority::Normal))
                .unwrap()
                .wait()
                .unwrap();
            walls.push(t0.elapsed().as_secs_f64());
        }
        let steady = &walls[1..];
        let capacity_rows_s = b_cap as f64 * steady.len() as f64 / steady.iter().sum::<f64>();
        drop(engine);

        let offered_rps = overload_factor * capacity_rows_s;
        let n_req = ((offered_rps * offer_secs) as usize).clamp(b_cap * 4, 50_000);
        // high-water: roughly half a deadline's worth of queue — deep
        // enough to keep batches full, shallow enough that surviving rows
        // still dispatch inside the deadline
        let high_water =
            ((capacity_rows_s * deadline.as_secs_f64() / 2.0) as usize).max(2 * b_cap);
        println!(
            "\n[overload] capacity ≈ {capacity_rows_s:.0} rows/s → offering \
             {offered_rps:.0} single-row req/s (×{overload_factor}) for \
             {offer_secs}s, deadline {deadline:?}, high-water {high_water} rows"
        );

        let mut otable = Table::new(&[
            "scenario", "reqs", "offered rps", "accepted", "rejected", "shed",
            "misses", "goodput",
        ]);
        let mut goodput_pair = (0.0f64, 0.0f64); // (shed-off, shed-on)
        for shed_on in [false, true] {
            let slo = if shed_on {
                SloConfig {
                    admission: true,
                    shed_high_water_rows: high_water,
                    client_quota_rows: 0,
                }
            } else {
                SloConfig {
                    admission: false,
                    shed_high_water_rows: 0,
                    client_quota_rows: 0,
                }
            };
            let scenario = format!("overload shed={}", if shed_on { "on" } else { "off" });
            let engine = Arc::new(Engine::new(heavy_config(slo)).unwrap());
            register(&engine);
            engine.warmup(heavy_task).unwrap();
            let mut rng = Rng::new(12);
            let mut handles = Vec::with_capacity(n_req);
            let mut rejected = 0usize;
            let t0 = Instant::now();
            for i in 0..n_req {
                let target = t0 + Duration::from_secs_f64(i as f64 / offered_rps);
                loop {
                    let now = Instant::now();
                    if now >= target {
                        break;
                    }
                    if target - now > Duration::from_millis(1) {
                        std::thread::sleep(target - now - Duration::from_micros(500));
                    } else {
                        std::thread::yield_now();
                    }
                }
                // mixed priority classes: shedding evicts low first
                let priority = match i % 3 {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => Priority::High,
                };
                let input = vec![rng.normal_f32(), rng.normal_f32()];
                match engine.submit_opts(
                    heavy_task,
                    0.5,
                    input,
                    1,
                    &dopri(Some(deadline), priority),
                ) {
                    Ok(h) => handles.push(h),
                    Err(_) => rejected += 1,
                }
            }
            let accepted = handles.len();
            let mut met = 0usize;
            for h in handles {
                if let Ok(resp) = h.wait() {
                    if resp.latency <= deadline {
                        met += 1;
                    }
                }
            }
            let metrics = engine.metrics();
            let shed = metrics.shed.load(Relaxed);
            let misses = metrics.deadline_misses.load(Relaxed);
            let goodput = met as f64 / n_req as f64;
            if shed_on {
                goodput_pair.1 = goodput;
            } else {
                goodput_pair.0 = goodput;
            }
            otable.row(&[
                scenario.clone(),
                n_req.to_string(),
                format!("{offered_rps:.0}"),
                accepted.to_string(),
                rejected.to_string(),
                shed.to_string(),
                misses.to_string(),
                format!("{goodput:.3}"),
            ]);
            scenarios_json.push(json::obj(vec![
                ("scenario", json::s(&scenario)),
                ("mode", json::s("inproc_openloop_overload")),
                ("task", json::s(heavy_task)),
                ("shedding", Value::Bool(shed_on)),
                ("overload_factor", json::num(overload_factor)),
                ("deadline_ms", json::num(deadline.as_secs_f64() * 1e3)),
                ("capacity_rows_per_s", json::num(capacity_rows_s)),
                ("offered_rps", json::num(offered_rps)),
                ("requests", json::num(n_req as f64)),
                ("accepted", json::num(accepted as f64)),
                ("rejected_at_submit", json::num(rejected as f64)),
                ("shed", json::num(shed as f64)),
                ("deadline_misses", json::num(misses as f64)),
                ("deadline_met", json::num(met as f64)),
                ("goodput", json::num(goodput)),
            ]));
            println!("[{scenario}] {}", metrics.report());
        }
        println!();
        otable.print();
        println!(
            "\ngoodput = deadline-met completions / all submitted requests \
             (admission rejects and shed rows count as failures). The shed=on \
             row must beat shed=off: refusing doomed work up front keeps \
             capacity on requests that can still meet their deadline."
        );
        overload_headline = Some((goodput_pair.1, goodput_pair.0));
    } else if overload_factor > 0.0 {
        println!(
            "\n[overload] skipped: the scenario needs the native backend's \
             synthetic heavy fixture"
        );
    }

    // ---- shadow-audit A/B + distribution shift: the numerical-health plane ----
    //
    // The same Poisson mixed-budget workload replayed three times: audit
    // off, audit sampling every completed request (rate 1.0, the worst
    // case), and audit-on with every input pushed far outside the
    // fixtures' training box. The off/on p50 pair lands in the bench
    // trajectory, where benchgate enforces the ≤10% audit-overhead bound;
    // the shifted run reports the drift scores and budget-breach counters
    // the health plane raises, and (with --health-prom) writes the
    // audit-enabled exposition for `benchgate --expo-check-health`.
    let audit_requests = args.get_usize("audit-requests");
    let mut audit_headline: Option<(f64, f64)> = None; // (off p50, on p50)
    if audit_requests > 0 {
        let mut audit_pair = (0.0f64, 0.0f64);
        let health_runs: [(&str, f64, bool); 3] = [
            ("audit off", 0.0, false),
            ("audit on", 1.0, false),
            ("audit on shifted", 1.0, true),
        ];
        for (label, audit_rate, shifted) in health_runs {
            let scenario = format!("health {label}");
            let mut cfg = engine_config(args.get_usize("workers"));
            cfg.audit.rate = audit_rate;
            let engine = Arc::new(Engine::new(cfg).unwrap());
            register(&engine);
            for t in &tasks {
                engine.warmup(t).unwrap();
            }
            let spec = WorkloadSpec {
                rate: args.get_f64("rate"),
                count: audit_requests,
                tasks: tasks.clone(),
                budgets: vec![(0.05f32, 0.6f64), (0.15, 0.3), (0.01, 0.1)],
            };
            let trace = spec.generate(&mut Rng::new(21));
            let mut rng = Rng::new(22);
            let t0 = Instant::now();
            let mut pending = Vec::with_capacity(trace.events.len());
            for ev in &trace.events {
                let target = t0 + Duration::from_secs_f64(ev.at_s);
                loop {
                    let now = Instant::now();
                    if now >= target {
                        break;
                    }
                    let gap = target - now;
                    if gap > Duration::from_millis(1) {
                        std::thread::sleep(gap - Duration::from_micros(500));
                    } else {
                        std::thread::yield_now();
                    }
                }
                let dim = dims[tasks.iter().position(|t| *t == ev.task).unwrap()];
                // in-distribution inputs sit inside the fixtures' training
                // box ([-1.5, 1.5]); the shifted run offsets far outside it
                let input: Vec<f32> = (0..dim)
                    .map(|_| {
                        let x = rng.normal_f32() * 0.5;
                        if shifted {
                            x + 9.0
                        } else {
                            x
                        }
                    })
                    .collect();
                pending.push(engine.submit(&ev.task, ev.budget, input).unwrap());
            }
            let mut latencies = Vec::with_capacity(pending.len());
            for handle in pending {
                latencies.push(handle.wait().unwrap().latency.as_secs_f64() * 1e3);
            }
            let wall = t0.elapsed().as_secs_f64();
            let achieved_rps = audit_requests as f64 / wall;
            let (p50, p95, p99) = (
                stats::percentile(&latencies, 50.0),
                stats::percentile(&latencies, 95.0),
                stats::percentile(&latencies, 99.0),
            );
            if !shifted {
                if audit_rate == 0.0 {
                    audit_pair.0 = p50;
                } else {
                    audit_pair.1 = p50;
                }
            }
            // drain the audit queue on this thread so the snapshot below
            // (and the exposition written for CI) reflects every sample
            let audited = engine.audit_flush();
            let mut drift_max = 0.0f64;
            let mut breaches = 0u64;
            let mut keys_json: Vec<Value> = Vec::new();
            if let Some(plane) = engine.audit() {
                for k in plane.snapshot() {
                    if let Some(d) = k.drift_score {
                        drift_max = drift_max.max(d);
                    }
                    breaches += k.breaches;
                    keys_json.push(json::obj(vec![
                        ("task", json::s(&k.task)),
                        ("variant", json::s(&k.variant)),
                        ("samples", json::num(k.samples as f64)),
                        ("err_p50", json::num(k.err_p50)),
                        ("budget", json::num(k.budget)),
                        ("status", json::s(k.budget_status())),
                        ("breaches", json::num(k.breaches as f64)),
                        (
                            "drift_score",
                            k.drift_score.map(json::num).unwrap_or(Value::Null),
                        ),
                    ]));
                }
            }
            let metrics = engine.metrics();
            table.row(&[
                scenario.clone(),
                "0".into(),
                audit_requests.to_string(),
                format!("{:.0}", spec.rate),
                format!("{achieved_rps:.0}"),
                format!("{p50:.2}"),
                format!("{p99:.2}"),
                format!("{:.2}", metrics.fill_ratio()),
                "-".into(),
                metrics.inflight_peak.load(Relaxed).to_string(),
            ]);
            scenarios_json.push(json::obj(vec![
                ("scenario", json::s(&scenario)),
                ("mode", json::s("inproc_poisson_audit")),
                ("audit_rate", json::num(audit_rate)),
                ("shifted", Value::Bool(shifted)),
                ("requests", json::num(audit_requests as f64)),
                ("throughput_rps", json::num(achieved_rps)),
                ("p50_ms", json::num(p50)),
                ("p95_ms", json::num(p95)),
                ("p99_ms", json::num(p99)),
                ("audited", json::num(audited as f64)),
                ("drift_score_max", json::num(drift_max)),
                ("budget_breaches", json::num(breaches as f64)),
                ("audit_keys", Value::Arr(keys_json)),
            ]));
            if audit_rate > 0.0 {
                println!(
                    "[{scenario}] audited={audited} drift_max={drift_max:.3} \
                     breaches={breaches}"
                );
            }
            if shifted {
                let hp = args.get("health-prom");
                if !hp.is_empty() {
                    std::fs::write(&hp, engine.render_prometheus())
                        .expect("write --health-prom");
                    println!("wrote audit-enabled exposition to {hp}");
                }
            }
        }
        println!(
            "\n[health] audit A/B p50: off {:.2} ms vs on {:.2} ms (rate 1.0)",
            audit_pair.0, audit_pair.1
        );
        audit_headline = Some(audit_pair);
    }

    // ---- cluster serving: K engines behind the consistent-hash router ----
    //
    // The multi-process deployment story: a LocalCluster of engine nodes
    // fronted by the router (the hyperrouter data path, in-process), one
    // pipelined client connection against the router's merged surface.
    // The steady run reports aggregate latency plus the router's merged
    // `cmd: "metrics"` view with per-node batch fill. The kill runs then
    // stop the primary node of one task halfway through, once with the
    // failover budget off and once on; goodput is the fraction of
    // requests answered Ok inside their deadline. The health poller is
    // slowed way down for those runs so retries — not ejection — are the
    // recovery mechanism under test.
    let cluster_nodes = args.get_usize("cluster-nodes");
    let mut cluster_headline: Option<(f64, f64, f64)> = None; // (p50, on, off)
    if cluster_nodes > 0 && matches!(backend, BackendKind::Native) {
        let creq = args.get_usize("cluster-requests").max(cluster_nodes * 8);
        let ctasks = ["cnf_a", "cnf_b"];
        let cluster_fixture: Vec<(&str, usize)> = ctasks.iter().map(|t| (*t, 8)).collect();
        let spawn_router = |nodes: Vec<String>, retries: usize, poll: Duration| {
            let router = Arc::new(Router::new(RouterConfig {
                nodes,
                retries,
                poll_interval: poll,
                eject_after: 2,
                connect_timeout: Duration::from_millis(500),
                ..Default::default()
            }));
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            {
                let router = Arc::clone(&router);
                std::thread::spawn(move || {
                    let _ = router.serve_listener(listener);
                });
            }
            (router, addr)
        };
        let connect = |addr: &str| {
            server::Client::connect_with(
                addr,
                Some(Duration::from_secs(2)),
                Some(Duration::from_secs(60)),
            )
            .unwrap()
        };
        let make_req = |i: usize, rng: &mut Rng, deadline: Option<Duration>| {
            // fixture cnf tasks are 2-dimensional; alternate tasks so the
            // ring places the stream across distinct nodes
            let mut req = InferRequest::single(
                ctasks[i % ctasks.len()],
                0.05,
                vec![rng.normal_f32(), rng.normal_f32()],
            );
            req.deadline_us = deadline.map(|d| d.as_micros() as u64);
            req
        };

        let mut ctable = Table::new(&[
            "scenario", "nodes", "reqs", "achieved rps", "p50 ms", "p99 ms",
            "ok", "failed", "goodput",
        ]);

        // steady state: no failures, aggregate latency + merged metrics
        {
            let cluster = LocalCluster::spawn(cluster_nodes, "bench_cluster", &cluster_fixture)
                .expect("spawn cluster");
            let (router, raddr) =
                spawn_router(cluster.addrs(), 2, Duration::from_millis(200));
            let mut client = connect(&raddr);
            let mut rng = Rng::new(17);
            let t0 = Instant::now();
            let mut sent_at: HashMap<u64, Instant> = HashMap::with_capacity(window);
            let mut latencies: Vec<f64> = Vec::with_capacity(creq);
            let mut next = 0usize;
            while next < creq.min(window) {
                let id = client.send(&make_req(next, &mut rng, None)).unwrap();
                sent_at.insert(id, Instant::now());
                next += 1;
            }
            while latencies.len() < creq {
                let reply = client.recv_reply().unwrap();
                let id = reply.id().expect("reply without id");
                let at = sent_at.remove(&id).expect("unmatched reply id");
                latencies.push(at.elapsed().as_secs_f64() * 1e3);
                if let InferReply::Err(e) = reply {
                    panic!("steady cluster request failed: {}", e.error);
                }
                if next < creq {
                    let id = client.send(&make_req(next, &mut rng, None)).unwrap();
                    sent_at.insert(id, Instant::now());
                    next += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let achieved_rps = creq as f64 / wall;
            let (p50, p95, p99) = (
                stats::percentile(&latencies, 50.0),
                stats::percentile(&latencies, 95.0),
                stats::percentile(&latencies, 99.0),
            );
            // the router's merged metrics: cluster totals + per-node fill
            let merged = client
                .request(&json::obj(vec![("cmd", json::s("metrics"))]))
                .expect("router metrics");
            let fill = merged.get("fill").and_then(Value::as_f64).unwrap_or(0.0);
            let per_node_fill: Vec<Value> = merged
                .get("per_node")
                .and_then(Value::as_arr)
                .map(|nodes| {
                    nodes
                        .iter()
                        .map(|n| n.get("fill").cloned().unwrap_or(Value::Null))
                        .collect()
                })
                .unwrap_or_default();
            ctable.row(&[
                "cluster steady".into(),
                cluster_nodes.to_string(),
                creq.to_string(),
                format!("{achieved_rps:.0}"),
                format!("{p50:.2}"),
                format!("{p99:.2}"),
                creq.to_string(),
                "0".into(),
                "1.000".into(),
            ]);
            scenarios_json.push(json::obj(vec![
                ("scenario", json::s("cluster steady")),
                ("mode", json::s("router_cluster")),
                ("nodes", json::num(cluster_nodes as f64)),
                ("requests", json::num(creq as f64)),
                ("window", json::num(window as f64)),
                ("throughput_rps", json::num(achieved_rps)),
                ("p50_ms", json::num(p50)),
                ("p95_ms", json::num(p95)),
                ("p99_ms", json::num(p99)),
                ("fill", json::num(fill)),
                ("per_node_fill", Value::Arr(per_node_fill)),
            ]));
            println!(
                "\n[cluster steady] {cluster_nodes} nodes, window={window}: \
                 p50 {p50:.2} ms, merged fill {fill:.2}"
            );
            router.stop();
            cluster_headline = Some((p50, 0.0, 0.0));
        }

        // kill one node mid-run: retries off, then on. The victim is the
        // ring primary of the first task, so roughly half the stream is
        // aimed at the node that disappears.
        let deadline = Duration::from_secs(2);
        let victim = Ring::new(cluster_nodes, RouterConfig::default().vnodes)
            .primary(Ring::key(ctasks[0], None))
            .expect("non-empty ring has a primary");
        let mut goodput_pair = (0.0f64, 0.0f64); // (off, on)
        for retries_on in [false, true] {
            let scenario =
                format!("cluster kill retries={}", if retries_on { "on" } else { "off" });
            let mut cluster =
                LocalCluster::spawn(cluster_nodes, "bench_cluster_kill", &cluster_fixture)
                    .expect("spawn cluster");
            // poll far slower than the run: ejection never happens, so any
            // recovery in the goodput numbers is the retry path alone
            let (router, raddr) = spawn_router(
                cluster.addrs(),
                if retries_on { 2 } else { 0 },
                Duration::from_secs(600),
            );
            let mut client = connect(&raddr);
            let mut rng = Rng::new(18);
            let mut sent_at: HashMap<u64, Instant> = HashMap::with_capacity(window);
            let mut ok_in_deadline = 0usize;
            let mut failed = 0usize;
            let mut done = 0usize;
            let mut next = 0usize;
            let mut killed = false;
            let t0 = Instant::now();
            while next < creq.min(window) {
                let id = client.send(&make_req(next, &mut rng, Some(deadline))).unwrap();
                sent_at.insert(id, Instant::now());
                next += 1;
            }
            while done < creq {
                let reply = client.recv_reply().unwrap();
                let id = reply.id().expect("reply without id");
                let at = sent_at.remove(&id).expect("unmatched reply id");
                done += 1;
                match reply {
                    InferReply::Ok(_) if at.elapsed() <= deadline => ok_in_deadline += 1,
                    InferReply::Ok(_) => failed += 1,
                    InferReply::Err(_) => failed += 1,
                }
                if !killed && next >= creq / 2 {
                    // mid-run node loss (graceful: drains, then the port
                    // goes dark — the router sees resets and refusals)
                    cluster.stop(victim).expect("stop victim node");
                    killed = true;
                }
                if next < creq {
                    let id =
                        client.send(&make_req(next, &mut rng, Some(deadline))).unwrap();
                    sent_at.insert(id, Instant::now());
                    next += 1;
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let goodput = ok_in_deadline as f64 / creq as f64;
            if retries_on {
                goodput_pair.1 = goodput;
            } else {
                goodput_pair.0 = goodput;
            }
            ctable.row(&[
                scenario.clone(),
                cluster_nodes.to_string(),
                creq.to_string(),
                format!("{:.0}", creq as f64 / wall),
                "-".into(),
                "-".into(),
                ok_in_deadline.to_string(),
                failed.to_string(),
                format!("{goodput:.3}"),
            ]);
            scenarios_json.push(json::obj(vec![
                ("scenario", json::s(&scenario)),
                ("mode", json::s("router_cluster_kill")),
                ("nodes", json::num(cluster_nodes as f64)),
                ("killed_node", json::num(victim as f64)),
                ("retries", json::num(if retries_on { 2.0 } else { 0.0 })),
                ("requests", json::num(creq as f64)),
                ("deadline_ms", json::num(deadline.as_secs_f64() * 1e3)),
                ("ok_in_deadline", json::num(ok_in_deadline as f64)),
                ("failed", json::num(failed as f64)),
                ("goodput", json::num(goodput)),
            ]));
            println!(
                "[{scenario}] killed node {victim} at {}/{creq}: \
                 {ok_in_deadline} ok, {failed} failed, goodput {goodput:.3}",
                creq / 2
            );
            router.stop();
            cluster.stop_all();
        }
        if let Some(h) = cluster_headline.as_mut() {
            h.1 = goodput_pair.1;
            h.2 = goodput_pair.0;
        }
        println!();
        ctable.print();
        println!(
            "\ncluster goodput = Ok-within-deadline replies / all requests \
             through the router. The retries=on row must beat retries=off: \
             with the poller slowed down, the failover budget is the only \
             thing standing between a dead primary and failed requests."
        );
    } else if cluster_nodes > 0 {
        println!(
            "\n[cluster] skipped: the router scenarios need the native \
             backend's LocalCluster fixture"
        );
    }

    println!();
    table.print();
    println!(
        "\nmixed-budget NFE/req should sit far below the tight-only scenario: \
         the policy routes everything it can to hypersolved variants. \
         'conc peak' ≥ 2 shows distinct queues overlapping on the pool. The \
         pipelined tcp rows measure the external API v1 surface (one \
         connection, {window} in flight, id-matched completions)."
    );

    // machine-readable summary in the shared bench schema, so the bench
    // trajectory is diffable PR over PR
    let doc = benchkit::bench_doc(
        "serving_throughput",
        vec![
            ("backend", json::s(&backend.to_string())),
            ("workers", json::num(resolved_workers as f64)),
            (
                "requests_per_scenario",
                json::num(args.get_usize("requests") as f64),
            ),
            ("offered_rate", json::num(args.get_f64("rate"))),
            ("matmul_threads", json::num(mm as f64)),
            ("tasks", Value::Arr(tasks.iter().map(|t| json::s(t)).collect())),
            ("scenarios", Value::Arr(scenarios_json)),
        ],
    );
    match benchkit::write_bench_json("BENCH_serving.json", &doc) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench JSON: {e}"),
    }
    if let Some((p50, rps)) = headline {
        let mut fields = vec![
            ("backend", json::s(&backend.to_string())),
            ("mixed_p50_ms", json::num(p50)),
            ("mixed_throughput_rps", json::num(rps)),
        ];
        if let Some((v1_p50, v2_p50)) = wide_headline {
            fields.push(("pipelined_big_v1_p50_ms", json::num(v1_p50)));
            fields.push(("pipelined_big_v2_p50_ms", json::num(v2_p50)));
        }
        if let Some((goodput_on, goodput_off)) = overload_headline {
            fields.push(("overload_goodput", json::num(goodput_on)));
            fields.push(("overload_goodput_baseline", json::num(goodput_off)));
            fields.push(("overload_factor", json::num(overload_factor)));
        }
        if let Some((off_p50, on_p50)) = audit_headline {
            fields.push(("audit_off_p50_ms", json::num(off_p50)));
            fields.push(("audit_on_p50_ms", json::num(on_p50)));
        }
        if let Some((p50, on, off)) = cluster_headline {
            fields.push(("cluster_nodes", json::num(cluster_nodes as f64)));
            fields.push(("cluster_p50_ms", json::num(p50)));
            fields.push(("cluster_kill_goodput_retries_on", json::num(on)));
            fields.push(("cluster_kill_goodput_retries_off", json::num(off)));
        }
        // engine-side stage breakdown of the headline scenario — benchgate
        // checks that queue+pad+exec p50s stay consistent with the total
        if let Some(sf) = headline_stages {
            fields.extend(sf);
        }
        let entry = benchkit::bench_doc("serving_throughput", fields);
        match benchkit::append_trajectory(entry) {
            Ok(path) => println!("appended to {}", path.display()),
            Err(e) => eprintln!("failed to append bench trajectory: {e}"),
        }
    }
}

/// Engine-side stage-latency breakdown, read from the request spans'
/// histograms: where a request's wall time actually went (queue wait, pad,
/// execute) as distinct from the client-observed percentiles above.
fn stage_fields(
    m: &hypersolvers::coordinator::CoordinatorMetrics,
) -> Vec<(&'static str, Value)> {
    let ms = |h: &stats::LatencyHistogram, pct: f64| json::num(h.percentile_us(pct) / 1e3);
    vec![
        ("stage_queue_p50_ms", ms(&m.queue_latency, 50.0)),
        ("stage_queue_p99_ms", ms(&m.queue_latency, 99.0)),
        ("stage_pad_p50_ms", ms(&m.pad_latency, 50.0)),
        ("stage_pad_p99_ms", ms(&m.pad_latency, 99.0)),
        ("stage_exec_p50_ms", ms(&m.exec_latency, 50.0)),
        ("stage_exec_p99_ms", ms(&m.exec_latency, 99.0)),
        ("stage_total_p50_ms", ms(&m.total_latency, 50.0)),
        ("stage_total_p99_ms", ms(&m.total_latency, 99.0)),
    ]
}
