//! Serving bench (ours) — the coordinator under a Poisson workload.
//!
//! This is the deployment story the paper's introduction motivates: tight
//! inference-time constraints. A Poisson trace of CNF sampling requests with
//! a mixed budget profile is replayed against the engine; reported:
//! throughput, latency percentiles, batch fill, NFE spent per request, and
//! the same workload forced through dopri5-only (no hypersolver variants)
//! for the compute saving the policy buys.

use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use hypersolvers::coordinator::{Engine, EngineConfig, Policy};
use hypersolvers::data::workload::WorkloadSpec;
use hypersolvers::util::artifacts::require_manifest;
use hypersolvers::util::benchkit::Table;
use hypersolvers::util::prng::Rng;
use hypersolvers::util::stats;

fn main() {
    let m = require_manifest();
    drop(m);
    let mut table = Table::new(&[
        "scenario", "reqs", "offered rps", "achieved rps", "p50 ms",
        "p99 ms", "fill", "NFE/req",
    ]);

    for (scenario, budgets) in [
        ("mixed budgets", vec![(0.05f32, 0.6f64), (0.15, 0.3), (0.01, 0.1)]),
        ("tight only (dopri5-ish)", vec![(0.0005, 1.0)]),
        ("loose only", vec![(0.3, 1.0)]),
    ] {
        let engine = Engine::new(EngineConfig {
            max_wait: Duration::from_millis(2),
            policy: Policy::MinMacs,
            ..Default::default()
        })
        .unwrap();
        engine.warmup("cnf_rings").unwrap();

        let spec = WorkloadSpec {
            rate: 2000.0,
            count: 2000,
            tasks: vec!["cnf_rings".into()],
            budgets,
        };
        let trace = spec.generate(&mut Rng::new(7));
        let mut rng = Rng::new(8);

        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(trace.events.len());
        for ev in &trace.events {
            // replay arrival times; sleep for long gaps, yield for short
            // ones — busy-spinning starves the dispatcher on 1 core
            let target = t0 + Duration::from_secs_f64(ev.at_s);
            loop {
                let now = Instant::now();
                if now >= target {
                    break;
                }
                let gap = target - now;
                if gap > Duration::from_millis(1) {
                    std::thread::sleep(gap - Duration::from_micros(500));
                } else {
                    std::thread::yield_now();
                }
            }
            let input = vec![rng.normal_f32(), rng.normal_f32()];
            pending.push(engine.submit(&ev.task, ev.budget, input).unwrap());
        }
        let mut latencies = Vec::with_capacity(pending.len());
        for rx in pending {
            let resp = rx.recv().unwrap();
            latencies.push(resp.latency.as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        let metrics = engine.metrics();
        let nfe_per_req = metrics.nfe_total.load(Relaxed) as f64
            / metrics.responses.load(Relaxed) as f64;
        table.row(&[
            scenario.into(),
            trace.events.len().to_string(),
            format!("{:.0}", spec.rate),
            format!("{:.0}", trace.events.len() as f64 / wall),
            format!("{:.2}", stats::percentile(&latencies, 50.0)),
            format!("{:.2}", stats::percentile(&latencies, 99.0)),
            format!("{:.2}", metrics.fill_ratio()),
            format!("{nfe_per_req:.1}"),
        ]);
        println!("[{scenario}] {}", metrics.report());
    }
    println!();
    table.print();
    println!(
        "\nmixed-budget NFE/req should sit far below the tight-only scenario: \
         the policy routes everything it can to hypersolved variants"
    );
}
