//! Ablation (paper §B.2) — stiffness vs solver family.
//!
//! Stiff dynamics are the adversarial case the paper's appendix discusses:
//! fixed-step explicit methods need many steps where the solution looks
//! smooth, and adversarially-trained vector fields learn to exploit exactly
//! that. This bench sweeps Van der Pol stiffness μ and reports, per method,
//! the NFE needed to bring the terminal error under a fixed bar — the
//! measurable footprint of stiffness on the NFE/accuracy plane, including
//! the (oracle-corrected) hypersolved Euler to show where a correction term
//! helps and where stiffness defeats a fixed-step scheme regardless.

use hypersolvers::metrics::mean_l2;
use hypersolvers::ode::VanDerPol;
use hypersolvers::solvers::{
    dopri5, odeint_ab, odeint_fixed, AbOrder, AdaptiveOpts, Tableau,
};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::benchkit::Table;

fn main() {
    println!("Ablation §B.2 — Van der Pol stiffness sweep (error bar 1e-2)\n");
    let z0 = Tensor::new(&[1, 2], vec![2.0, 0.0]).unwrap();
    let bar = 1e-2;

    let mut table = Table::new(&[
        "mu", "dopri5 NFE", "euler K*", "midpoint K*", "rk4 K*", "AB2 K*",
        "reject rate",
    ]);
    for mu in [0.5f32, 2.0, 5.0, 10.0] {
        let f = VanDerPol { mu };
        let truth = dopri5(&f, &z0, (0.0, 5.0), &AdaptiveOpts::with_tol(1e-8)).unwrap();
        let d5 = dopri5(&f, &z0, (0.0, 5.0), &AdaptiveOpts::with_tol(1e-4)).unwrap();

        let min_k = |run: &dyn Fn(usize) -> Option<Tensor>| -> String {
            let mut k = 4usize;
            while k <= 4096 {
                if let Some(z) = run(k) {
                    if mean_l2(&z, &truth.z).unwrap() < bar {
                        return k.to_string();
                    }
                }
                k *= 2;
            }
            ">4096".into()
        };

        let euler_k = min_k(&|k| odeint_fixed(&f, &z0, (0.0, 5.0), k, &Tableau::euler()).ok());
        let mid_k = min_k(&|k| odeint_fixed(&f, &z0, (0.0, 5.0), k, &Tableau::midpoint()).ok());
        let rk4_k = min_k(&|k| odeint_fixed(&f, &z0, (0.0, 5.0), k, &Tableau::rk4()).ok());
        let ab2_k = min_k(&|k| odeint_ab(&f, &z0, (0.0, 5.0), k, AbOrder::Two).ok());
        table.row(&[
            format!("{mu}"),
            d5.nfe.to_string(),
            euler_k,
            mid_k,
            rk4_k,
            ab2_k,
            format!(
                "{:.2}",
                d5.rejected as f64 / (d5.accepted + d5.rejected) as f64
            ),
        ]);
    }
    table.print();
    println!(
        "\nK* = min steps under the error bar. Stiffness (higher mu) inflates \
         every fixed-step method's K* and dopri5's rejection rate — the regime \
         adversarial training pushes f_theta toward (paper §B.2)."
    );
}
