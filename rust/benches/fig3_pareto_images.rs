//! Fig. 3 — image-classification Pareto fronts (both synthetic datasets):
//! (a) test-accuracy drop vs NFE, (b) terminal MAPE vs GMACs.
//!
//! Dense (solver, K) sweep on the native path (no PJRT compile per point),
//! exactly the series the paper plots: euler / midpoint / rk4 sweeps against
//! a single HyperEuler trained at K=10 by residual fitting. The paper's
//! claim to reproduce: HyperEuler is pareto-dominant at low NFE/GMACs and
//! higher-order methods only catch up at high NFE.

use hypersolvers::metrics::{accuracy, mape, pareto_front, ParetoPoint};
use hypersolvers::nn::ImageModel;
use hypersolvers::solvers::{odeint_fixed, odeint_hyper, Tableau};
use hypersolvers::util::artifacts::{load_blob, load_labels, require_manifest};
use hypersolvers::util::benchkit::Table;

fn main() {
    let m = require_manifest();
    for ds in ["img_smnist", "img_scifar"] {
        run_dataset(&m, ds);
    }
}

fn run_dataset(m: &hypersolvers::runtime::Manifest, ds: &str) {
    let task = m.task(ds).unwrap();
    let model = ImageModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(m, ds, "z0");
    let truth = load_blob(m, ds, "truth");
    let labels = load_labels(m, ds, "y");
    let truth_acc = accuracy(&model.hy(&truth).unwrap(), &labels).unwrap();
    let hw = model.hw;
    let mac_f = model.field.macs_hw(hw);
    let mac_g = model.hyper.macs_hw(hw);

    println!(
        "\nFig. 3 — {ds}: acc*(dopri5)={truth_acc:.3} MAC_f={mac_f} MAC_g={mac_g}"
    );
    let mut table = Table::new(&[
        "method", "K", "NFE", "GMACs", "MAPE", "acc", "acc drop %",
    ]);
    let mut points_nfe = Vec::new();
    let mut points_mac = Vec::new();

    let base: Vec<(Tableau, Vec<usize>)> = vec![
        (Tableau::euler(), vec![1, 2, 4, 8, 16, 32]),
        (Tableau::midpoint(), vec![1, 2, 4, 8, 16]),
        (Tableau::rk4(), vec![1, 2, 4, 8]),
    ];
    for (tab, ks) in &base {
        for &k in ks {
            let zt = odeint_fixed(&model.field, &z0, task.s_span, k, tab).unwrap();
            record(
                &model, &zt, &truth, &labels, truth_acc,
                &format!("{}", tab.name), k, tab.stages() as u64 * k as u64,
                (tab.stages() as u64 * k as u64) * mac_f,
                &mut table, &mut points_nfe, &mut points_mac,
            );
        }
    }
    // HyperEuler sweep — one extra g eval per step
    for &k in &[1usize, 2, 4, 8, 16] {
        let zt = odeint_hyper(
            &model.field, &model.hyper, &z0, task.s_span, k, &Tableau::euler(),
        )
        .unwrap();
        record(
            &model, &zt, &truth, &labels, truth_acc,
            "hypereuler", k, k as u64,
            k as u64 * (mac_f + mac_g),
            &mut table, &mut points_nfe, &mut points_mac,
        );
    }
    table.print();

    let front = pareto_front(&points_nfe);
    println!("MAPE-NFE pareto front: {}", fmt_front(&front));
    let front_mac = pareto_front(&points_mac);
    println!("MAPE-GMAC pareto front: {}", fmt_front(&front_mac));
    let hyper_on_front = front
        .iter()
        .chain(front_mac.iter())
        .filter(|p| p.label.starts_with("hypereuler") && p.cost <= 4.0 * 1e9_f64.max(1.0))
        .count();
    println!(
        "hypereuler appears {} times on the low-NFE fronts \
         (paper: pareto-dominant at low NFE)",
        hyper_on_front
    );
}

#[allow(clippy::too_many_arguments)]
fn record(
    model: &ImageModel,
    zt: &hypersolvers::tensor::Tensor,
    truth: &hypersolvers::tensor::Tensor,
    labels: &[i32],
    truth_acc: f64,
    name: &str,
    k: usize,
    nfe: u64,
    macs: u64,
    table: &mut Table,
    points_nfe: &mut Vec<ParetoPoint>,
    points_mac: &mut Vec<ParetoPoint>,
) {
    let mp = mape(zt, truth).unwrap();
    let acc = accuracy(&model.hy(zt).unwrap(), labels).unwrap();
    let drop = (truth_acc - acc) * 100.0;
    table.row(&[
        name.to_string(),
        k.to_string(),
        nfe.to_string(),
        format!("{:.4}", macs as f64 / 1e9),
        format!("{mp:.4}"),
        format!("{acc:.3}"),
        format!("{drop:.2}"),
    ]);
    let label = format!("{name}_k{k}");
    points_nfe.push(ParetoPoint {
        label: label.clone(),
        cost: nfe as f64,
        error: mp,
    });
    points_mac.push(ParetoPoint {
        label,
        cost: macs as f64,
        error: mp,
    });
}

fn fmt_front(front: &[ParetoPoint]) -> String {
    front
        .iter()
        .map(|p| p.label.as_str())
        .collect::<Vec<_>>()
        .join(" -> ")
}
