//! Fig. 8 — trajectory tracking: global-truncation-error pareto under
//! *trajectory fitting*.
//!
//! The tracking HyperEuler was trained by minimising the global error along
//! the whole mesh (paper §3.2 / §C.1). This bench sweeps NFE for euler /
//! midpoint / rk4 / HyperEuler and reports the mean global error E_K at the
//! terminal mesh point plus the mean error along the trajectory against
//! dopri5(1e-6) checkpoints.
//!
//! Paper claim: in the 10–25 NFE range HyperEuler beats midpoint and rk4.

use hypersolvers::metrics::{mean_l2, pareto_front, ParetoPoint};
use hypersolvers::nn::TrackingModel;
use hypersolvers::solvers::{
    odeint_fixed_traj, odeint_hyper_traj, Tableau,
};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::{fmt_sci, Table};

fn main() {
    let m = require_manifest();
    let task = m.task("tracking").unwrap();
    let model = TrackingModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "tracking", "z0");
    // dense dopri5 mesh exported by aot.py: (26, B, 2) checkpoints
    let mesh = load_blob(&m, "tracking", "mesh");
    let mesh_pts = mesh.shape()[0];
    let b = mesh.shape()[1];
    let d = mesh.shape()[2];
    let mesh_at = |i: usize| -> Tensor {
        Tensor::new(&[b, d], mesh.data()[i * b * d..(i + 1) * b * d].to_vec()).unwrap()
    };

    println!("Fig. 8 — tracking global error (trajectory-fitted HyperEuler)\n");
    let mut table = Table::new(&[
        "method", "K", "NFE", "terminal E_K", "mean traj error",
    ]);
    let mut points = Vec::new();

    // K choices give the paper's 5–50 NFE x-axis; mesh has 25 segments so
    // K must divide 25 for exact checkpoint comparison
    let base: Vec<(Tableau, Vec<usize>)> = vec![
        (Tableau::euler(), vec![5, 25]),
        (Tableau::midpoint(), vec![5, 25]),
        (Tableau::rk4(), vec![5]),
    ];
    let eval = |traj: &[Tensor]| -> (f64, f64) {
        // trajectory points at mesh indices: traj has K+1 points over [0,1],
        // mesh has 26 over [0,1] → compare where grids coincide
        let k = traj.len() - 1;
        let stride = (mesh_pts - 1) / k;
        let mut total = 0.0;
        for (i, z) in traj.iter().enumerate() {
            total += mean_l2(z, &mesh_at(i * stride)).unwrap();
        }
        let terminal = mean_l2(traj.last().unwrap(), &mesh_at(mesh_pts - 1)).unwrap();
        (terminal, total / traj.len() as f64)
    };

    for (tab, ks) in &base {
        for &k in ks {
            let traj =
                odeint_fixed_traj(&model.field, &z0, task.s_span, k, tab).unwrap();
            let (term, avg) = eval(&traj);
            let nfe = tab.stages() * k;
            table.row(&[
                tab.name.clone(),
                k.to_string(),
                nfe.to_string(),
                fmt_sci(term),
                fmt_sci(avg),
            ]);
            points.push(ParetoPoint {
                label: format!("{}_k{k}", tab.name),
                cost: nfe as f64,
                error: term,
            });
        }
    }
    for &k in &[5usize, 25] {
        let traj = odeint_hyper_traj(
            &model.field, &model.hyper, &z0, task.s_span, k, &Tableau::euler(),
        )
        .unwrap();
        let (term, avg) = eval(&traj);
        table.row(&[
            "hypereuler".into(),
            k.to_string(),
            k.to_string(),
            fmt_sci(term),
            fmt_sci(avg),
        ]);
        points.push(ParetoPoint {
            label: format!("hypereuler_k{k}"),
            cost: k as f64,
            error: term,
        });
    }
    table.print();

    let front = pareto_front(&points);
    println!(
        "\nglobal-error pareto front: {}",
        front
            .iter()
            .map(|p| p.label.as_str())
            .collect::<Vec<_>>()
            .join(" -> ")
    );
    println!("paper: HyperEuler most efficient in the 10-25 NFE range");
}
