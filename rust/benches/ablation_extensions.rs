//! Ablation (paper §6) — beyond fixed-step explicit hypersolvers.
//!
//! Exercises the two §6 extensions on the trained CNF models:
//!
//! 1. **Adaptive hypersolver** — the ε^{p+1}·g_ω term doubles as a free
//!    local-error estimate, so the hypersolved scheme can adapt its own
//!    step size (`odeint_hyper_adaptive`). Compared against dopri5 and
//!    fixed-K hypersolving on NFE and terminal MAPE.
//! 2. **Predictor-corrector** — Adams-Bashforth-Moulton with the trained
//!    HyperHeun net correcting the predictor, vs plain ABM and AB2.

use hypersolvers::metrics::mape;
use hypersolvers::nn::CnfModel;
use hypersolvers::solvers::{
    dopri5, odeint_ab, odeint_abm, odeint_abm_plain, odeint_hyper,
    odeint_hyper_adaptive, AbOrder, AdaptiveOpts, Tableau,
};
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::Table;

fn main() {
    let m = require_manifest();
    let task = m.task("cnf_rings").unwrap();
    let model = CnfModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, "cnf_rings", "z0");
    let truth = load_blob(&m, "cnf_rings", "truth");

    println!("Ablation §6.1 — adaptive hypersolver (trained HyperHeun, rings CNF)\n");
    let mut t1 = Table::new(&["method", "NFE", "MAPE", "steps acc/rej"]);
    let d5 = dopri5(&model.field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-4)).unwrap();
    t1.row(&[
        "dopri5(1e-4)".into(),
        d5.nfe.to_string(),
        format!("{:.4}", mape(&d5.z, &truth).unwrap()),
        format!("{}/{}", d5.accepted, d5.rejected),
    ]);
    for k in [1usize, 2, 4] {
        let z = odeint_hyper(
            &model.field, &model.hyper, &z0, task.s_span, k, &Tableau::heun(),
        )
        .unwrap();
        t1.row(&[
            format!("hyperheun K={k} (fixed)"),
            (2 * k).to_string(),
            format!("{:.4}", mape(&z, &truth).unwrap()),
            "-".into(),
        ]);
    }
    for tol in [1e-2f32, 1e-3] {
        let r = odeint_hyper_adaptive(
            &model.field,
            &model.hyper,
            &z0,
            task.s_span,
            &Tableau::heun(),
            &AdaptiveOpts::with_tol(tol),
        )
        .unwrap();
        t1.row(&[
            format!("hyperheun adaptive({tol:.0e})"),
            r.nfe.to_string(),
            format!("{:.4}", mape(&r.z, &truth).unwrap()),
            format!("{}/{}", r.accepted, r.rejected),
        ]);
    }
    t1.print();

    println!("\nAblation §6.2 — predictor-corrector with hypersolver predictor\n");
    let mut t2 = Table::new(&["method", "NFE/step", "K", "MAPE"]);
    for k in [4usize, 8, 16] {
        let ab2 = odeint_ab(&model.field, &z0, task.s_span, k, AbOrder::Two).unwrap();
        let abm = odeint_abm_plain(&model.field, &z0, task.s_span, k).unwrap();
        let abm_h = odeint_abm(
            &model.field, &z0, task.s_span, k, Some(&model.hyper),
        )
        .unwrap();
        t2.row(&[
            "AB2".into(), "1".into(), k.to_string(),
            format!("{:.4}", mape(&ab2, &truth).unwrap()),
        ]);
        t2.row(&[
            "ABM (PECE)".into(), "2".into(), k.to_string(),
            format!("{:.4}", mape(&abm, &truth).unwrap()),
        ]);
        t2.row(&[
            "ABM + hyper predictor".into(), "2".into(), k.to_string(),
            format!("{:.4}", mape(&abm_h, &truth).unwrap()),
        ]);
    }
    t2.print();
    println!(
        "\n(the HyperHeun net was trained for K=1 Heun residuals; its reuse \
         inside other schemes is the paper's §6 proposal — gains concentrate \
         at coarse K where its training regime applies)"
    );
}
