//! Fig. 4 — wall-clock speedup of fixed-step methods over dopri5 (image
//! Neural ODE).
//!
//! Protocol (paper §4.1): each method runs the *minimum number of steps*
//! that keeps test-accuracy loss vs dopri5 under 0.1%; wall-clock is the
//! mean time to solve one test batch. Both paths are measured:
//!   native  — the rust tensor stack (apples-to-apples across methods);
//!   pjrt    — the fused AOT executables the coordinator actually serves.
//!
//! Paper claim to reproduce: HyperEuler ~8× faster than dopri5; Euler needs
//! more steps than HyperEuler to reach the accuracy bar, so it lands slower.

use hypersolvers::metrics::accuracy;
use hypersolvers::nn::ImageModel;
use hypersolvers::runtime::Executor;
use hypersolvers::solvers::{
    dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, Tableau,
};
use hypersolvers::util::artifacts::{load_blob, load_labels, require_manifest};
use hypersolvers::util::benchkit::{Bench, Table};

fn main() {
    let m = require_manifest();
    let ds = "img_smnist";
    let task = m.task(ds).unwrap();
    let model = ImageModel::load(&m.weights_path(task)).unwrap();
    let z0 = load_blob(&m, ds, "z0");
    let labels = load_labels(&m, ds, "y");
    let truth = load_blob(&m, ds, "truth");
    let acc_star = accuracy(&model.hy(&truth).unwrap(), &labels).unwrap();
    println!("Fig. 4 — wall-clock vs dopri5 ({ds}), acc* = {acc_star:.4}");
    println!("accuracy constraint: drop <= 0.1% (paper protocol)\n");

    // find min K per method meeting the accuracy bar
    let find_k = |tab: &Tableau, hyper: bool| -> Option<usize> {
        for k in 1..=64usize {
            let zt = if hyper {
                odeint_hyper(&model.field, &model.hyper, &z0, task.s_span, k, tab)
                    .unwrap()
            } else {
                odeint_fixed(&model.field, &z0, task.s_span, k, tab).unwrap()
            };
            let acc = accuracy(&model.hy(&zt).unwrap(), &labels).unwrap();
            if acc_star - acc <= 0.001 {
                return Some(k);
            }
        }
        None
    };

    let bench = Bench::with_budget(400);
    let mut table = Table::new(&[
        "method", "min K", "NFE", "native ms/batch", "speedup vs dopri5",
    ]);

    // dopri5 baseline (native)
    let opts = AdaptiveOpts::with_tol(1e-4);
    let d5 = bench.run("dopri5", || {
        let _ = dopri5(&model.field, &z0, task.s_span, &opts).unwrap();
    });
    let d5_nfe = dopri5(&model.field, &z0, task.s_span, &opts).unwrap().nfe;
    table.row(&[
        "dopri5(1e-4)".into(),
        "-".into(),
        d5_nfe.to_string(),
        format!("{:.2}", d5.mean_ms()),
        "1.0x".into(),
    ]);

    let methods: Vec<(&str, Tableau, bool)> = vec![
        ("euler", Tableau::euler(), false),
        ("midpoint", Tableau::midpoint(), false),
        ("rk4", Tableau::rk4(), false),
        ("hypereuler", Tableau::euler(), true),
    ];
    for (name, tab, hyper) in methods {
        let Some(k) = find_k(&tab, hyper) else {
            table.row(&[
                name.into(), ">64".into(), "-".into(), "-".into(), "-".into(),
            ]);
            continue;
        };
        let mm = bench.run(name, || {
            if hyper {
                let _ = odeint_hyper(
                    &model.field, &model.hyper, &z0, task.s_span, k, &tab,
                )
                .unwrap();
            } else {
                let _ = odeint_fixed(&model.field, &z0, task.s_span, k, &tab).unwrap();
            }
        });
        let nfe = tab.stages() * k;
        table.row(&[
            name.into(),
            k.to_string(),
            nfe.to_string(),
            format!("{:.2}", mm.mean_ms()),
            format!("{:.1}x", d5.mean_ms() / mm.mean_ms()),
        ]);
    }
    table.print();

    // PJRT path: the fused executables the coordinator serves
    println!("\nPJRT fused-executable path (batch of {}):", task.batch());
    let exec = Executor::spawn().unwrap();
    let h = exec.handle();
    let mut t2 = Table::new(&["variant", "NFE", "pjrt ms/batch", "speedup"]);
    let mut d5_ms = None;
    for vname in ["dopri5", "rk4_k4", "euler_k8", "hypereuler_k2"] {
        let Some(v) = task.variant(vname) else { continue };
        h.load(vname, m.hlo_path(&v.hlo)).unwrap();
        let input = z0.data().to_vec();
        let shape = v.in_shape.clone();
        let mm = bench.run(vname, || {
            let _ = h.run(vname, input.clone(), &shape).unwrap();
        });
        if vname == "dopri5" {
            d5_ms = Some(mm.mean_ms());
        }
        t2.row(&[
            vname.into(),
            v.nfe.to_string(),
            format!("{:.2}", mm.mean_ms()),
            d5_ms
                .map(|d| format!("{:.1}x", d / mm.mean_ms()))
                .unwrap_or("-".into()),
        ]);
    }
    t2.print();
}
