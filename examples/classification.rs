//! Image classification with a convolutional Neural ODE, solver by solver.
//!
//! Demonstrates the paper's §4.1 trade-off interactively: classify the
//! exported eval batch with euler / midpoint / rk4 / HyperEuler at a chosen
//! step count and compare accuracy + cost, on both the native path and the
//! fused PJRT classify executables (image → logits).
//!
//! ```bash
//! cargo run --release --example classification -- --dataset img_smnist --k 2
//! ```

use hypersolvers::metrics::accuracy;
use hypersolvers::nn::ImageModel;
use hypersolvers::runtime::Executor;
use hypersolvers::solvers::{odeint_fixed, odeint_hyper, Tableau};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::artifacts::{load_blob, load_labels, require_manifest};
use hypersolvers::util::benchkit::Table;
use hypersolvers::util::cli::Cli;

fn main() {
    let args = Cli::new("classification — conv Neural ODE solver comparison")
        .opt("dataset", "img_smnist", "img_smnist | img_scifar")
        .opt("k", "2", "fixed-step count K")
        .parse_env();
    let ds = args.get("dataset");
    let k = args.get_usize("k");

    let m = require_manifest();
    let task = m.task(&ds).expect("dataset artifacts");
    let model = ImageModel::load(&m.weights_path(task)).expect("weights");
    let z0 = load_blob(&m, &ds, "z0");
    let labels = load_labels(&m, &ds, "y");
    let truth = load_blob(&m, &ds, "truth");
    let acc_star = accuracy(&model.hy(&truth).unwrap(), &labels).unwrap();

    println!("{ds}: dopri5 reference accuracy {acc_star:.3}  (K={k})\n");
    let mut table = Table::new(&["method", "NFE", "accuracy", "acc drop %"]);
    for (name, tab, hyper) in [
        ("euler", Tableau::euler(), false),
        ("midpoint", Tableau::midpoint(), false),
        ("rk4", Tableau::rk4(), false),
        ("hypereuler", Tableau::euler(), true),
    ] {
        let zt = if hyper {
            odeint_hyper(&model.field, &model.hyper, &z0, task.s_span, k, &tab)
                .unwrap()
        } else {
            odeint_fixed(&model.field, &z0, task.s_span, k, &tab).unwrap()
        };
        let acc = accuracy(&model.hy(&zt).unwrap(), &labels).unwrap();
        table.row(&[
            name.into(),
            (tab.stages() * k).to_string(),
            format!("{acc:.3}"),
            format!("{:.2}", (acc_star - acc) * 100.0),
        ]);
    }
    table.print();

    // the fused image→logits executables (the deployable classify path)
    let x = load_blob(&m, &ds, "x");
    let exec = Executor::spawn().expect("pjrt");
    let h = exec.handle();
    println!("\nfused PJRT classify executables (image -> logits, batch {}):", x.shape()[0]);
    for tag in ["hypereuler_k2_logits", "euler_k8_logits", "rk4_k4_logits"] {
        let hlo = m.hlo_path(&format!("{ds}_{tag}.hlo.txt"));
        if !hlo.exists() {
            continue;
        }
        h.load(tag, hlo).unwrap();
        let out = h.run(tag, x.data().to_vec(), x.shape()).unwrap();
        let logits = Tensor::new(&[x.shape()[0], 10], out[0].clone()).unwrap();
        let acc = accuracy(&logits, &labels).unwrap();
        println!("  {tag:<22} accuracy {acc:.3}");
    }
}
