//! End-to-end driver (the repo's mandated E2E validation): bring up the
//! full serving stack on the real trained CNF models and push a live
//! workload through every layer — Pallas/JAX AOT artifacts → PJRT executor
//! → policy → dynamic batcher → responses — reporting latency, throughput
//! and sample quality. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example cnf_serving -- --requests 2000 --rate 1500
//! ```

use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use hypersolvers::coordinator::{Engine, EngineConfig, Policy};
use hypersolvers::data::densities::{hist_l1, histogram2d};
use hypersolvers::data::workload::WorkloadSpec;
use hypersolvers::tensor::Tensor;
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::cli::Cli;
use hypersolvers::util::prng::Rng;
use hypersolvers::util::stats;

fn main() {
    let args = Cli::new("cnf_serving — end-to-end hypersolver serving demo")
        .opt("requests", "2000", "number of requests to replay")
        .opt("rate", "1500", "offered requests/second")
        .opt("budget", "0.08", "MAPE budget of the main traffic class")
        .opt("max-wait-ms", "2", "batching deadline")
        .parse_env();

    let manifest = require_manifest();
    let densities: Vec<String> = manifest
        .tasks
        .keys()
        .filter(|k| k.starts_with("cnf_"))
        .cloned()
        .collect();

    let engine = Engine::new(EngineConfig {
        max_wait: Duration::from_millis(args.get_usize("max-wait-ms") as u64),
        policy: Policy::MinMacs,
        ..Default::default()
    })
    .expect("engine");
    println!("warming up {} CNF tasks (PJRT compile)...", densities.len());
    for d in &densities {
        engine.warmup(d).expect("warmup");
    }

    let spec = WorkloadSpec {
        rate: args.get_f64("rate"),
        count: args.get_usize("requests"),
        tasks: densities.clone(),
        budgets: vec![
            (args.get_f64("budget") as f32, 0.8), // main traffic
            (0.01, 0.1),                          // premium accuracy
            (0.5, 0.1),                           // best-effort
        ],
    };
    let trace = spec.generate(&mut Rng::new(2026));
    println!(
        "replaying {} requests over {:.2}s across {:?}",
        trace.events.len(),
        trace.duration_s(),
        densities
    );

    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(trace.events.len());
    for ev in &trace.events {
        let target = t0 + Duration::from_secs_f64(ev.at_s);
        loop {
            let now = Instant::now();
            if now >= target {
                break;
            }
            let gap = target - now;
            if gap > Duration::from_millis(1) {
                std::thread::sleep(gap - Duration::from_micros(500));
            } else {
                std::thread::yield_now();
            }
        }
        let input = vec![rng.normal_f32(), rng.normal_f32()];
        pending.push((
            ev.task.clone(),
            engine.submit(&ev.task, ev.budget, input).expect("submit"),
        ));
    }
    let mut latencies = Vec::with_capacity(pending.len());
    let mut outputs: std::collections::BTreeMap<String, Vec<f32>> = Default::default();
    let mut variant_counts: std::collections::BTreeMap<String, usize> = Default::default();
    for (task, handle) in pending {
        let resp = handle.wait().expect("response");
        latencies.push(resp.latency.as_secs_f64() * 1e3);
        outputs.entry(task).or_default().extend(&resp.output);
        *variant_counts.entry(resp.variant).or_default() += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== serving results ==");
    println!(
        "throughput: {:.0} req/s (offered {:.0})   wall {:.2}s",
        trace.events.len() as f64 / wall,
        spec.rate,
        wall
    );
    println!(
        "latency ms: p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}",
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 95.0),
        stats::percentile(&latencies, 99.0),
        stats::max(&latencies),
    );
    let metrics = engine.metrics();
    println!("coordinator: {}", metrics.report());
    println!("variants served: {variant_counts:?}");
    println!(
        "mean NFE/request: {:.1} (dopri5 alone would spend ~{} per request)",
        metrics.nfe_total.load(Relaxed) as f64 / metrics.responses.load(Relaxed) as f64,
        manifest
            .task(&densities[0])
            .unwrap()
            .variant("dopri5")
            .map(|v| v.nfe)
            .unwrap_or(0),
    );

    // sample quality: served samples vs the training data distribution
    println!("\n== sample quality (histogram L1 vs data; lower is better) ==");
    for d in &densities {
        let Some(served) = outputs.get(d) else { continue };
        let n = served.len() / 2;
        let served_t = Tensor::new(&[n, 2], served.clone()).unwrap();
        let data = load_blob(&manifest, d, "density_samples");
        let l1 = hist_l1(
            &histogram2d(&served_t, 14, 4.0),
            &histogram2d(&data, 14, 4.0),
        );
        println!("  {d:<18} {n:>5} samples  L1 {l1:.3}");
    }
}
