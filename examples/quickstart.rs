//! Quickstart: load the trained CNF, solve it three ways, see the paper's
//! point in 30 lines.
//!
//! ```bash
//! make artifacts            # once: trains + AOT-exports everything
//! cargo run --release --example quickstart
//! ```

use hypersolvers::metrics::mape;
use hypersolvers::nn::CnfModel;
use hypersolvers::solvers::{
    dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, Tableau,
};
use hypersolvers::util::artifacts::{load_blob, require_manifest};

fn main() {
    let manifest = require_manifest();
    let task = manifest.task("cnf_rings").expect("cnf_rings artifacts");
    let model = CnfModel::load(&manifest.weights_path(task)).expect("weights");
    let z0 = load_blob(&manifest, "cnf_rings", "z0"); // 256 noise samples

    // 1. reference: adaptive dopri5 (what Neural ODE papers actually run)
    let reference = dopri5(&model.field, &z0, task.s_span, &AdaptiveOpts::with_tol(1e-6))
        .expect("dopri5");
    println!("dopri5      : {:>4} NFE  (reference)", reference.nfe);

    // 2. classical fixed-step at TWO function evaluations: fails
    let heun = odeint_fixed(&model.field, &z0, task.s_span, 1, &Tableau::heun())
        .expect("heun");
    println!(
        "heun K=1    : {:>4} NFE  MAPE {:.4}",
        2,
        mape(&heun, &reference.z).unwrap()
    );

    // 3. the paper: same 2 NFE + the trained hypersolver correction
    let hyper = odeint_hyper(
        &model.field,
        &model.hyper,
        &z0,
        task.s_span,
        1,
        &Tableau::heun(),
    )
    .expect("hyperheun");
    println!(
        "hyperheun K=1: {:>4} NFE  MAPE {:.4}   <- dopri5-grade samples at 2 NFE",
        2,
        mape(&hyper, &reference.z).unwrap()
    );
}
