//! Trajectory tracking (paper §C.1): a Galerkin-flavoured Neural ODE tracks
//! β(s) = [sin 2πs, cos 2πs]; the trajectory-fitted HyperEuler keeps the
//! rollout on the reference path at a fraction of the NFEs.
//!
//! Prints an ASCII plot of one tracked trajectory per method plus the
//! global error table — the lightweight control/real-time story of the
//! paper's introduction.
//!
//! ```bash
//! cargo run --release --example trajectory_tracking -- --k 10
//! ```

use hypersolvers::metrics::mean_l2;
use hypersolvers::nn::TrackingModel;
use hypersolvers::solvers::{odeint_fixed_traj, odeint_hyper_traj, Tableau};
use hypersolvers::tensor::Tensor;
use hypersolvers::util::artifacts::{load_blob, require_manifest};
use hypersolvers::util::benchkit::Table;
use hypersolvers::util::cli::Cli;

fn main() {
    let args = Cli::new("trajectory_tracking — periodic signal tracking demo")
        .opt("k", "10", "fixed-step count K (NFE for euler/hypereuler)")
        .parse_env();
    let k = args.get_usize("k");

    let m = require_manifest();
    let task = m.task("tracking").expect("tracking artifacts");
    let model = TrackingModel::load(&m.weights_path(task)).expect("weights");
    let z0 = load_blob(&m, "tracking", "z0");
    let mesh = load_blob(&m, "tracking", "mesh");
    let (mesh_pts, b, d) = (mesh.shape()[0], mesh.shape()[1], mesh.shape()[2]);
    let mesh_at = |i: usize| {
        Tensor::new(&[b, d], mesh.data()[i * b * d..(i + 1) * b * d].to_vec()).unwrap()
    };

    println!("tracking β(s) over s ∈ [0,1], K = {k}\n");
    let mut table = Table::new(&["method", "NFE", "terminal E_K"]);
    let mut plots: Vec<(String, Vec<(f32, f32)>)> = Vec::new();

    for (name, tab, hyper) in [
        ("euler", Tableau::euler(), false),
        ("midpoint", Tableau::midpoint(), false),
        ("hypereuler", Tableau::euler(), true),
    ] {
        let traj = if hyper {
            odeint_hyper_traj(&model.field, &model.hyper, &z0, task.s_span, k, &tab)
                .unwrap()
        } else {
            odeint_fixed_traj(&model.field, &z0, task.s_span, k, &tab).unwrap()
        };
        let term = mean_l2(traj.last().unwrap(), &mesh_at(mesh_pts - 1)).unwrap();
        table.row(&[
            name.into(),
            (tab.stages() * k).to_string(),
            format!("{term:.4}"),
        ]);
        // first sample's (x, y) path for the ascii plot
        plots.push((
            name.to_string(),
            traj.iter()
                .map(|z| (z.data()[0], z.data()[1]))
                .collect(),
        ));
    }
    table.print();

    // reference path of sample 0 from the dopri5 mesh
    let reference: Vec<(f32, f32)> = (0..mesh_pts)
        .map(|i| {
            let z = mesh_at(i);
            (z.data()[0], z.data()[1])
        })
        .collect();
    plots.push(("dopri5".into(), reference));

    println!("\nsample-0 phase portrait (x vs y), 41x21 ascii:");
    ascii_plot(&plots);
}

fn ascii_plot(series: &[(String, Vec<(f32, f32)>)]) {
    let (w, h) = (41usize, 21usize);
    let mut grid = vec![b' '; w * h];
    let marks = [b'e', b'm', b'H', b'*'];
    let (lim, _) = series
        .iter()
        .flat_map(|(_, pts)| pts.iter())
        .fold((1.0f32, ()), |(lim, ()), (x, y)| {
            (lim.max(x.abs()).max(y.abs()), ())
        });
    for (si, (_, pts)) in series.iter().enumerate() {
        for (x, y) in pts {
            let cx = (((x / lim) + 1.0) / 2.0 * (w - 1) as f32).round() as usize;
            let cy = ((1.0 - (y / lim)) / 2.0 * (h - 1) as f32).round() as usize;
            grid[cy.min(h - 1) * w + cx.min(w - 1)] = marks[si % marks.len()];
        }
    }
    for row in 0..h {
        println!("  {}", String::from_utf8_lossy(&grid[row * w..(row + 1) * w]));
    }
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{}={}", marks[i % marks.len()] as char, n))
        .collect();
    println!("  [{}]", legend.join("  "));
}
