"""Field/net building blocks: shapes, activations, optimiser, schedules."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import fields as F


def test_mlp_shapes():
    key = jax.random.PRNGKey(0)
    layers = F.init_mlp(key, [5, 16, 3])
    x = jnp.ones((7, 5), jnp.float32)
    y = F.mlp_apply(layers, x)
    assert y.shape == (7, 3)


def test_linear_apply_kernel_and_ref_agree():
    key = jax.random.PRNGKey(1)
    p = F.init_linear(key, 64, 64)
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 64), jnp.float32)
    a = F.linear_apply(p, x, "tanh", use_kernels=True)
    b = F.linear_apply(p, x, "tanh", use_kernels=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_time_features():
    assert F.time_features(0.5, "concat").shape == (1,)
    ff = F.time_features(0.25, "fourier3")
    assert ff.shape == (6,)
    np.testing.assert_allclose(ff[0], np.sin(2 * np.pi * 0.25), rtol=1e-5)
    with pytest.raises(ValueError):
        F.time_features(0.1, "poly")


def test_mlp_field_apply_batches():
    key = jax.random.PRNGKey(3)
    params = F.init_mlp_field(key, 2, (32,), "fourier3")
    z = jnp.ones((9, 2), jnp.float32)
    out = F.mlp_field_apply(params, 0.3, z, "fourier3")
    assert out.shape == (9, 2)
    # time-dependence: different s must give different output
    out2 = F.mlp_field_apply(params, 0.8, z, "fourier3")
    assert not np.allclose(out, out2)


def test_depth_cat():
    x = jnp.zeros((2, 3, 4, 4), jnp.float32)
    y = F.depth_cat(0.7, x)
    assert y.shape == (2, 4, 4, 4)
    np.testing.assert_allclose(y[:, 3], 0.7 * np.ones((2, 4, 4)))


def test_conv_field_shapes():
    key = jax.random.PRNGKey(4)
    params = F.init_conv_field(key, 6, 16)
    z = jnp.ones((2, 6, 16, 16), jnp.float32)
    out = F.conv_field_apply(params, 0.5, z)
    assert out.shape == z.shape


def test_prelu_negative_slope():
    p = {"alpha": jnp.array([0.5, 0.1], jnp.float32)}
    x = jnp.array([[-2.0, -2.0]], jnp.float32)[:, :, None, None]
    y = F.prelu_apply(p, x)
    np.testing.assert_allclose(y[0, :, 0, 0], [-1.0, -0.2], rtol=1e-6)


def test_image_model_end_to_end_shapes():
    key = jax.random.PRNGKey(5)
    params = F.init_image_model(key, 1, 6, 16, 16, 10)
    x = jnp.ones((3, 1, 16, 16), jnp.float32)
    z0 = F.image_hx_apply(params, x)
    assert z0.shape == (3, 6, 16, 16)
    logits = F.image_hy_apply(params, z0)
    assert logits.shape == (3, 10)


def test_hyper_mlp_apply():
    key = jax.random.PRNGKey(6)
    hp = F.init_hyper_mlp(key, 2, (16,))
    z = jnp.ones((5, 2), jnp.float32)
    out = F.hyper_mlp_apply(hp, 0.1, 0.0, z, z)
    assert out.shape == (5, 2)


def test_hyper_cnn_apply():
    key = jax.random.PRNGKey(7)
    hp = F.init_hyper_cnn(key, 6, 16)
    z = jnp.ones((2, 6, 16, 16), jnp.float32)
    out = F.hyper_cnn_apply(hp, 0.1, 0.0, z, z)
    assert out.shape == z.shape


def test_adamw_minimises_quadratic():
    params = {"x": jnp.array([5.0, -3.0], jnp.float32)}
    opt = F.adamw_init(params)
    loss_fn = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(300):
        grads = jax.grad(loss_fn)(params)
        params, opt = F.adamw_update(grads, opt, params, lr=0.1)
    assert float(loss_fn(params)) < 1e-3


def test_adamw_weight_decay_shrinks_params():
    params = {"x": jnp.array([1.0], jnp.float32)}
    opt = F.adamw_init(params)
    zero = {"x": jnp.array([0.0], jnp.float32)}
    p1, _ = F.adamw_update(zero, opt, params, lr=1.0, weight_decay=0.1)
    assert float(p1["x"][0]) < 1.0


def test_cosine_lr_endpoints():
    lr0 = float(F.cosine_lr(jnp.int32(0), 100, 1e-2, 1e-4))
    lr_end = float(F.cosine_lr(jnp.int32(100), 100, 1e-2, 1e-4))
    assert abs(lr0 - 1e-2) < 1e-8
    assert abs(lr_end - 1e-4) < 1e-8
    mid = float(F.cosine_lr(jnp.int32(50), 100, 1e-2, 1e-4))
    assert 1e-4 < mid < 1e-2
