"""hypothesis property tests over the JAX solver layer (mirrors the rust
`properties` suite so both language stacks carry the same invariants)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import solvers as S

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

A = jnp.array([[0.0, 1.0], [-1.0, 0.0]], jnp.float32)
rot = lambda s, z: z @ A.T


def rot_exact(z0, s):
    c, si = np.cos(s), np.sin(s)
    R = jnp.asarray(np.array([[c, -si], [si, c]]), jnp.float32)
    return z0 @ R.T


@given(
    x=st.floats(-2, 2), y=st.floats(-2, 2),
    name=st.sampled_from(["euler", "midpoint", "heun", "rk4", "alpha0.4"]),
)
def test_flow_composition(x, y, name):
    """One solve over [0,1] equals two half-solves at matched meshes."""
    z0 = jnp.array([[x, y]], jnp.float32)
    tab = S.solver_by_name(name)
    whole = S.odeint_fixed(rot, z0, (0.0, 1.0), 8, tab)
    half = S.odeint_fixed(rot, z0, (0.0, 0.5), 4, tab)
    rest = S.odeint_fixed(rot, half, (0.5, 1.0), 4, tab)
    np.testing.assert_allclose(whole, rest, atol=1e-5)


@given(x=st.floats(-2, 2), y=st.floats(0.1, 2))
def test_rk4_preserves_rotation_norm(x, y):
    z0 = jnp.array([[x, y]], jnp.float32)
    z1 = S.odeint_fixed(rot, z0, (0.0, 1.0), 32, S.RK4)
    assert abs(
        float(jnp.linalg.norm(z1)) - float(jnp.linalg.norm(z0))
    ) < 1e-4 * (1 + float(jnp.linalg.norm(z0)))


@given(omega=st.floats(0.5, 4.0))
def test_dopri5_matches_exact_rotation(omega):
    f = lambda s, z: omega * (z @ A.T)
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    zT, nfe = S.odeint_dopri5(f, z0, (0.0, 1.0), 1e-6, 1e-6)
    exact = rot_exact(z0, -omega)  # clockwise by omega
    np.testing.assert_allclose(zT, exact, atol=1e-4)
    assert int(nfe) % 7 == 0


@given(k=st.integers(2, 16))
def test_trajectory_endpoint_consistency(k):
    z0 = jnp.array([[0.7, -0.3]], jnp.float32)
    traj = S.odeint_fixed(rot, z0, (0.0, 1.0), int(k), S.HEUN,
                          return_traj=True)
    direct = S.odeint_fixed(rot, z0, (0.0, 1.0), int(k), S.HEUN)
    assert traj.shape[0] == k + 1
    np.testing.assert_allclose(traj[-1], direct, rtol=1e-6)


@given(
    batch=st.integers(1, 8),
    name=st.sampled_from(["euler", "heun", "rk4"]),
)
def test_batch_independence(batch, name):
    """Solving a batch together equals solving each sample alone — no
    cross-sample leakage in the vectorised solvers."""
    rng = np.random.default_rng(batch)
    z0 = jnp.asarray(rng.normal(size=(batch, 2)), jnp.float32)
    tab = S.solver_by_name(name)
    together = S.odeint_fixed(rot, z0, (0.0, 1.0), 6, tab)
    for i in range(batch):
        alone = S.odeint_fixed(rot, z0[i : i + 1], (0.0, 1.0), 6, tab)
        np.testing.assert_allclose(together[i : i + 1], alone, atol=1e-6)


@given(alpha=st.floats(0.25, 1.0))
def test_hyper_g_zero_reduces_to_base_alpha_family(alpha):
    z0 = jnp.array([[0.5, 0.5]], jnp.float32)
    tab = S.alpha_tableau(float(alpha))
    g0 = lambda e, s, z, dz: jnp.zeros_like(z)
    zh = S.odeint_hyper(rot, g0, z0, (0.0, 1.0), 5, tab, use_kernels=False)
    zb = S.odeint_fixed(rot, z0, (0.0, 1.0), 5, tab)
    np.testing.assert_allclose(zh, zb, rtol=1e-6)
