"""Pallas kernels vs pure-jnp oracles — the CORE L1 correctness signal.

hypothesis sweeps shapes (including non-multiples of the tile sizes, odd
batch dims, the tiny-problem oracle-dispatch path) and checks allclose.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear_act, hyper_step, rk_combine
from compile.kernels.ref import (
    act,
    hyper_step_ref,
    linear_act_ref,
    rk_combine_ref,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# fused_linear_act
# ---------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 7, 32, 64, 128, 200]),
    k=st.sampled_from([2, 3, 16, 64, 67]),
    n=st.sampled_from([1, 10, 64, 128]),
    kind=st.sampled_from(["id", "tanh", "relu", "softplus"]),
    seed=st.integers(0, 2**16),
)
def test_linear_act_matches_oracle(m, k, n, kind, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    out = fused_linear_act(x, w, b, kind)
    ref = linear_act_ref(x, w, b, kind)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_linear_act_large_tiled_path():
    # well above the oracle-dispatch threshold: exercises the real grid
    rng = np.random.default_rng(0)
    x, w, b = rand(rng, 256, 128), rand(rng, 128, 256), rand(rng, 256)
    out = fused_linear_act(x, w, b, "tanh")
    np.testing.assert_allclose(
        out, linear_act_ref(x, w, b, "tanh"), rtol=2e-5, atol=2e-5
    )


def test_linear_act_rejects_bad_shapes():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        fused_linear_act(rand(rng, 4, 3), rand(rng, 5, 2), rand(rng, 2))


def test_act_unknown_kind_raises():
    with pytest.raises(ValueError):
        act(jnp.zeros((2,)), "gelu")


def test_linear_act_composes_under_jit():
    # The kernels are INFERENCE-path ops (training uses the ref path:
    # pallas-interpret bodies do not autodiff). They must still compose
    # under an outer jit, which is how the AOT exporter lowers them.
    rng = np.random.default_rng(1)
    x, w, b = rand(rng, 64, 32), rand(rng, 32, 64), rand(rng, 64)

    @jax.jit
    def chain(x):
        h = fused_linear_act(x, w, b, "tanh")
        return fused_linear_act(h, w.T, b[:32], "id")

    out = chain(x)
    ref = linear_act_ref(
        linear_act_ref(x, w, b, "tanh"), w.T, b[:32], "id"
    )
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# hyper_step
# ---------------------------------------------------------------------------


@given(
    shape=st.sampled_from([(3,), (8, 2), (8, 512), (2, 6, 16, 16), (4, 1000)]),
    eps=st.sampled_from([0.01, 0.1, 0.5, 1.0]),
    order=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_hyper_step_matches_oracle(shape, eps, order, seed):
    rng = np.random.default_rng(seed)
    z, psi, g = rand(rng, *shape), rand(rng, *shape), rand(rng, *shape)
    out = hyper_step(z, psi, g, eps, order)
    ref = hyper_step_ref(z, psi, g, eps, order)
    assert out.shape == shape
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_hyper_step_zero_g_is_base_update():
    rng = np.random.default_rng(3)
    z, psi = rand(rng, 16, 128), rand(rng, 16, 128)
    out = hyper_step(z, psi, jnp.zeros_like(z), 0.25, 2)
    np.testing.assert_allclose(out, z + 0.25 * psi, rtol=1e-6)


def test_hyper_step_order_scaling():
    # the correction term must scale as eps^{p+1}
    rng = np.random.default_rng(4)
    z = jnp.zeros((4, 512), jnp.float32)
    psi = jnp.zeros_like(z)
    g = rand(rng, 4, 512)
    for p in (1, 2, 4):
        out = hyper_step(z, psi, g, 0.5, p)
        np.testing.assert_allclose(out, (0.5 ** (p + 1)) * g, rtol=1e-5)


# ---------------------------------------------------------------------------
# rk_combine
# ---------------------------------------------------------------------------


@given(
    shape=st.sampled_from([(5,), (8, 64), (16, 256), (2, 6, 8, 8)]),
    p=st.integers(1, 7),
    eps=st.sampled_from([0.05, 0.2, 1.0]),
    seed=st.integers(0, 2**16),
)
def test_rk_combine_matches_oracle(shape, p, eps, seed):
    rng = np.random.default_rng(seed)
    z = rand(rng, *shape)
    stages = rand(rng, p, *shape)
    b = rng.normal(size=p).tolist()
    out = rk_combine(z, stages, b, eps)
    ref = rk_combine_ref(z, stages, jnp.array(b, jnp.float32), eps)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_rk_combine_euler_weights():
    rng = np.random.default_rng(5)
    z = rand(rng, 8, 256)
    stages = rand(rng, 1, 8, 256)
    out = rk_combine(z, stages, [1.0], 0.1)
    np.testing.assert_allclose(out, z + 0.1 * stages[0], rtol=1e-5,
                               atol=1e-6)


def test_rk_combine_zero_weights_identity():
    rng = np.random.default_rng(6)
    z = rand(rng, 8, 256)
    stages = rand(rng, 3, 8, 256)
    out = rk_combine(z, stages, [0.0, 0.0, 0.0], 0.7)
    np.testing.assert_allclose(out, z, rtol=1e-6)
