"""Empirical checks of the paper's theoretical results.

Theorem 1 (local truncation error O(δ ε^{p+1})) and Proposition 1 (vector
field training sensitivity ‖Δf‖ ≤ η L_θ ‖Γ(∇L)‖) — both verified on real
trained-ish fields rather than toy linear systems.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import fields as F
from compile import solvers as S


def make_field(key):
    params = F.init_mlp_field(key, 2, (32, 32), "concat")
    f = lambda s, z: F.mlp_field_apply(params, s, z, "concat")
    return params, f


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------


def test_theorem1_local_error_scales_with_delta():
    """e_k ≤ O(δ ε^{p+1}): corrupt the exact residual by a controlled δ and
    check the hypersolved local error scales linearly in δ."""
    key = jax.random.PRNGKey(0)
    params, f = make_field(key)
    z0 = jax.random.normal(jax.random.PRNGKey(1), (64, 2), jnp.float32)
    eps = 0.25
    tab = S.EULER

    z1, _ = S.odeint_dopri5(f, z0, (0.0, eps), 1e-8, 1e-8)
    # exact residual R (eq. 6)
    direction = S.psi(f, tab, 0.0, z0, eps)
    resid = (z1 - z0 - eps * direction) / eps ** (tab.order + 1)

    noise = jax.random.normal(jax.random.PRNGKey(2), resid.shape, jnp.float32)
    noise = noise / jnp.linalg.norm(noise, axis=1, keepdims=True)

    errs = []
    deltas = [0.0, 0.05, 0.2]
    for delta in deltas:
        g = lambda e, s, z, dz, d=delta: resid + d * noise
        zh = S.odeint_hyper(f, g, z0, (0.0, eps), 1, tab, use_kernels=False)
        errs.append(float(jnp.mean(jnp.linalg.norm(zh - z1, axis=1))))
    # δ=0 → error at the f32/dopri5 floor
    assert errs[0] < 1e-4, errs
    # linear scaling: e(δ) ≈ δ ε^{p+1}
    for delta, e in zip(deltas[1:], errs[1:]):
        expected = delta * eps ** (tab.order + 1)
        assert 0.5 * expected < e < 2.0 * expected, (delta, e, expected)


def test_theorem1_order_in_eps():
    """With a fixed-quality g (the true ε-independent leading residual),
    the hypersolved local error keeps the ε^{p+1}... actually improves to
    ε^{p+2} since the leading term is cancelled — either way it must beat
    the base solver's ε^{p+1} by at least one order."""
    key = jax.random.PRNGKey(3)
    params, f = make_field(key)
    z0 = jax.random.normal(jax.random.PRNGKey(4), (32, 2), jnp.float32)
    tab = S.EULER

    def local_errors(scheme):
        errs = []
        for eps in (0.2, 0.1):
            z1, _ = S.odeint_dopri5(f, z0, (0.0, eps), 1e-8, 1e-8)
            errs.append(
                float(jnp.mean(jnp.linalg.norm(scheme(eps) - z1, axis=1)))
            )
        return errs

    base = local_errors(
        lambda eps: S.odeint_fixed(f, z0, (0.0, eps), 1, tab)
    )
    base_order = np.log2(base[0] / base[1])

    # g := the true leading residual at small eps (≈ ½ z̈)
    eps0 = 1e-3
    z1_small, _ = S.odeint_dopri5(f, z0, (0.0, eps0), 1e-10, 1e-10)
    resid_lead = (z1_small - z0 - eps0 * f(0.0, z0)) / eps0**2
    g = lambda e, s, z, dz: resid_lead

    hyper = local_errors(
        lambda eps: S.odeint_hyper(f, g, z0, (0.0, eps), 1, tab,
                                   use_kernels=False)
    )
    hyper_order = np.log2(hyper[0] / hyper[1])
    assert base_order > 1.5  # euler local error is O(ε²)
    # cancelling the leading residual keeps (at f32, on a generic nonlinear
    # field) at least the base order while shrinking the constant hard:
    assert hyper_order > base_order - 0.3, (base_order, hyper_order)
    # the ε→0 leading term is only part of R at finite ε; a >2× error cut
    # at both ε values is what cancelling it buys on this field
    assert hyper[0] < base[0] / 2.0 and hyper[1] < base[1] / 2.0, (base, hyper)


# ---------------------------------------------------------------------------
# Proposition 1
# ---------------------------------------------------------------------------


def test_prop1_field_drift_bounded_by_lr():
    """‖f_{θ+ηΓ} − f_θ‖ ≤ η L ‖Γ‖: the drift of the vector field under one
    optimizer step is linear in η — the quantity that governs hypersolver
    reuse across training iterations (§6)."""
    key = jax.random.PRNGKey(5)
    params, _ = make_field(key)

    # a surrogate gradient direction Γ of unit scale
    gamma = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) / np.sqrt(p.size), params
    )

    z = jax.random.normal(jax.random.PRNGKey(6), (128, 2), jnp.float32)

    def drift(eta):
        moved = jax.tree_util.tree_map(
            lambda p, g: p + eta * g, params, gamma
        )
        f0 = F.mlp_field_apply(params, 0.3, z, "concat")
        f1 = F.mlp_field_apply(moved, 0.3, z, "concat")
        return float(jnp.mean(jnp.linalg.norm(f1 - f0, axis=1)))

    etas = [1e-3, 1e-2, 1e-1]
    drifts = [drift(e) for e in etas]
    # monotone and (near η→0) linear in η
    assert drifts[0] < drifts[1] < drifts[2]
    ratio10 = drifts[1] / drifts[0]
    assert 5.0 < ratio10 < 20.0, drifts  # ≈10 for linear scaling


def test_prop1_residual_drift_tracks_field_drift():
    """Consequence for hypersolver reuse: small parameter steps perturb the
    residual target R by an amount of the same order as the field drift —
    a pretrained g_ω stays an O(δ+drift) approximator after a step."""
    key = jax.random.PRNGKey(7)
    params, f = make_field(key)
    z0 = jax.random.normal(jax.random.PRNGKey(8), (64, 2), jnp.float32)
    eps = 0.5
    tab = S.HEUN

    def residual(p):
        fp = lambda s, z: F.mlp_field_apply(p, s, z, "concat")
        z1, _ = S.odeint_dopri5(fp, z0, (0.0, eps), 1e-7, 1e-7)
        direction = S.psi(fp, tab, 0.0, z0, eps)
        return (z1 - z0 - eps * direction) / eps ** (tab.order + 1)

    r0 = residual(params)
    for eta in [1e-3, 1e-2]:
        moved = jax.tree_util.tree_map(
            lambda p: p + eta * jnp.ones_like(p) / np.sqrt(p.size), params
        )
        dr = float(jnp.mean(jnp.linalg.norm(residual(moved) - r0, axis=1)))
        # drift stays proportional to eta (no blow-up), tested at 1 order
        assert dr < 50 * eta, (eta, dr)
