"""Task-level smoke + invariants: data generators, losses, short training."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import fields as F
from compile import solvers as S
from compile.tasks import cnf as C
from compile.tasks import images as I
from compile.tasks import tracking as T


# ---------------------------------------------------------------------------
# CNF
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", C.DENSITIES)
def test_density_samplers(name):
    rng = np.random.default_rng(0)
    x = C.sample_density(name, 500, rng)
    assert x.shape == (500, 2) and x.dtype == np.float32
    assert np.isfinite(x).all()
    assert np.abs(x).max() < 10.0


def test_density_sampler_deterministic():
    a = C.sample_density("pinwheel", 100, np.random.default_rng(7))
    b = C.sample_density("pinwheel", 100, np.random.default_rng(7))
    np.testing.assert_array_equal(a, b)


def test_density_unknown_raises():
    with pytest.raises(KeyError):
        C.sample_density("two_moons", 10, np.random.default_rng(0))


def test_aug_field_trace_matches_autodiff():
    key = jax.random.PRNGKey(0)
    params = C.init_cnf(key)
    z = jax.random.normal(jax.random.PRNGKey(1), (4, 2), jnp.float32)
    u = jnp.concatenate([z, jnp.zeros((4, 1), jnp.float32)], axis=1)
    du = C.aug_field(params, 0.3, u)
    # check the trace channel against a full jacobian
    def single(zi):
        return C.cnf_field(params, 0.3, zi[None])[0]

    for i in range(4):
        J = jax.jacrev(single)(z[i])
        np.testing.assert_allclose(du[i, 2], -jnp.trace(J), rtol=1e-4,
                                   atol=1e-5)


def test_cnf_nll_finite_and_training_reduces_it():
    key = jax.random.PRNGKey(0)
    params, _ = C.train_cnf(key, "rings", iters=2, batch=64)
    x = jnp.asarray(C.sample_density("rings", 64, np.random.default_rng(3)))
    before = float(C.nll_loss(params, x))
    params2, _ = C.train_cnf(key, "rings", iters=60, batch=64)
    after = float(C.nll_loss(params2, x))
    assert np.isfinite(before) and np.isfinite(after)
    assert after < before


def test_hyperheun_residual_loss_positive():
    key = jax.random.PRNGKey(0)
    params = C.init_cnf(key)
    hp = C.init_hyperheun(jax.random.PRNGKey(1))
    z0 = jax.random.normal(jax.random.PRNGKey(2), (16, 2), jnp.float32)
    f = lambda s, z: C.cnf_field(params, s, z)
    z1, _ = S.odeint_dopri5(f, z0, C.S_SPAN, 1e-5, 1e-5)
    loss = float(C.residual_loss(hp, params, z0, z1, S.HEUN))
    assert np.isfinite(loss) and loss > 0


def test_fit_hyperheun_reduces_residual():
    key = jax.random.PRNGKey(0)
    params, _ = C.train_cnf(key, "rings", iters=30, batch=64)
    _, d_short = C.fit_hyperheun(jax.random.PRNGKey(1), params, iters=5)
    _, d_long = C.fit_hyperheun(jax.random.PRNGKey(1), params, iters=150)
    assert d_long < d_short


# ---------------------------------------------------------------------------
# Images
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(I.DATASETS))
def test_image_dataset(name):
    rng = np.random.default_rng(0)
    x, y = I.make_dataset(name, 40, rng)
    assert x.shape == (40, I.DATASETS[name], I.HW, I.HW)
    assert y.shape == (40,) and y.min() >= 0 and y.max() < I.N_CLASSES
    assert np.isfinite(x).all()


def test_image_dataset_classes_distinguishable():
    # class templates must differ: mean intra-class distance < inter-class
    rng = np.random.default_rng(1)
    xs = []
    for c in range(3):
        imgs = np.stack(
            [I._render_stroke(c, rng) for _ in range(8)]
        ).reshape(8, -1)
        xs.append(imgs)
    intra = np.mean(
        [np.linalg.norm(x - x.mean(0), axis=1).mean() for x in xs]
    )
    inter = np.mean(
        [
            np.linalg.norm(xs[i].mean(0) - xs[j].mean(0))
            for i in range(3)
            for j in range(i + 1, 3)
        ]
    )
    assert inter > intra, (inter, intra)


def test_image_classify_shapes():
    params = I.init_model(jax.random.PRNGKey(0), "smnist")
    x = jnp.ones((4, 1, I.HW, I.HW), jnp.float32)
    logits = I.classify(params, x, 2, S.MIDPOINT)
    assert logits.shape == (4, I.N_CLASSES)
    hp = I.init_hyper(jax.random.PRNGKey(1))
    logits_h = I.classify_hyper(params, hp, x, 2, S.EULER)
    assert logits_h.shape == (4, I.N_CLASSES)


def test_image_training_improves_accuracy():
    params, _ = I.train_model(jax.random.PRNGKey(0), "smnist", iters=60,
                              batch=32)
    x, y = I.make_dataset("smnist", 128, np.random.default_rng(9))
    acc = I.accuracy(I.classify(params, jnp.asarray(x), 2, S.MIDPOINT),
                     jnp.asarray(y))
    assert acc > 0.5, acc  # 10 classes: chance is 0.1


def test_residual_loss_mesh_runs():
    params = I.init_model(jax.random.PRNGKey(0), "smnist")
    hp = I.init_hyper(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    x, _ = I.make_dataset("smnist", 4, rng)
    z0 = F.image_hx_apply(params, jnp.asarray(x))
    grid = np.linspace(0, 1, 4)
    f = lambda s, z: I.field(params, s, z)
    mesh = S.dopri5_mesh(f, z0, list(grid), 1e-3, 1e-3)
    loss = float(I.residual_loss_mesh(hp, params, mesh, grid, S.EULER))
    assert np.isfinite(loss) and loss > 0


# ---------------------------------------------------------------------------
# Tracking
# ---------------------------------------------------------------------------


def test_beta_periodic():
    np.testing.assert_allclose(T.beta(0.0), T.beta(1.0), atol=1e-6)
    assert T.beta(jnp.array([0.0, 0.5])).shape == (2, 2)


def test_tracking_training_reduces_loss():
    p0 = T.init_field(jax.random.PRNGKey(0))
    z0 = jnp.asarray(
        np.asarray(T.beta(0.0))[None] + 0.1 * np.random.default_rng(0).normal(size=(8, 2)),
        jnp.float32,
    )
    before = float(T.tracking_loss(p0, z0))
    params, _ = T.train_tracker(jax.random.PRNGKey(0), iters=80, batch=32)
    after = float(T.tracking_loss(params, z0))
    assert after < before


def test_trajectory_fitting_reduces_global_error():
    params, _ = T.train_tracker(jax.random.PRNGKey(0), iters=60, batch=32)
    hp0 = T.init_hyper(jax.random.PRNGKey(5))
    hp, _ = T.fit_hyper(jax.random.PRNGKey(5), params, iters=120, batch=32)
    z0 = jnp.asarray(
        np.asarray(T.beta(0.0))[None] + 0.3 * np.random.default_rng(1).normal(size=(16, 2)),
        jnp.float32,
    )
    f = lambda s, z: T.field(params, s, z)
    truth = S.dopri5_mesh(f, z0, list(np.linspace(0, 1, 11)), 1e-6, 1e-6)
    err_before = float(T.trajectory_loss(hp0, params, z0, truth, 10))
    err_after = float(T.trajectory_loss(hp, params, z0, truth, 10))
    assert err_after < err_before
