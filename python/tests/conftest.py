import os
import sys

# Tests run either from python/ (Makefile) or the repo root; make the
# `compile` package importable in both cases.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
