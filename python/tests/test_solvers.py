"""L2 solver correctness: tableau consistency, convergence orders, dopri5
accuracy, alpha-family identities, hypersolver plumbing (Theorem 1
empirically)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import solvers as S

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

ALL_FIXED = [S.EULER, S.MIDPOINT, S.HEUN, S.RK4, S.alpha_tableau(0.3)]


# ---------------------------------------------------------------------------
# Tableau consistency (classical order conditions)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tab", ALL_FIXED + [S.DOPRI5], ids=lambda t: t.name)
def test_tableau_b_sums_to_one(tab):
    assert abs(sum(tab.b) - 1.0) < 1e-12


@pytest.mark.parametrize("tab", ALL_FIXED + [S.DOPRI5], ids=lambda t: t.name)
def test_tableau_c_equals_row_sums(tab):
    for i, row in enumerate(tab.a):
        assert abs(sum(row) - tab.c[i]) < 1e-9, f"row {i}"


def test_dopri5_embedded_weights_sum_to_one():
    assert abs(sum(S.DOPRI5.b_err) - 1.0) < 1e-12


@pytest.mark.parametrize("tab", [S.MIDPOINT, S.HEUN, S.alpha_tableau(0.7)],
                         ids=lambda t: t.name)
def test_second_order_condition(tab):
    # sum_i b_i c_i = 1/2 for order 2
    assert abs(sum(b * c for b, c in zip(tab.b, tab.c)) - 0.5) < 1e-12


def test_alpha_family_recovers_midpoint_and_heun():
    mid = S.alpha_tableau(0.5)
    assert np.allclose(mid.b, S.MIDPOINT.b) and np.allclose(mid.c, S.MIDPOINT.c)
    heun = S.alpha_tableau(1.0)
    assert np.allclose(heun.b, S.HEUN.b) and np.allclose(heun.c, S.HEUN.c)


def test_solver_by_name():
    assert S.solver_by_name("rk4") is S.RK4
    assert S.solver_by_name("alpha0.25").c[1] == 0.25
    with pytest.raises(KeyError):
        S.solver_by_name("ab2")
    with pytest.raises(ValueError):
        S.solver_by_name("alpha-1")


# ---------------------------------------------------------------------------
# Convergence orders on a rotation field (closed form: z(s) = R(-s) z0)
# ---------------------------------------------------------------------------

A = jnp.array([[0.0, 1.0], [-1.0, 0.0]], jnp.float32)


def rot_field(s, z):
    return z @ A.T


def rot_exact(s):
    c, si = np.cos(s), np.sin(s)
    return jnp.asarray(np.array([[c, -si]]) @ np.array([[1.0], [0.0]])), None


EXPECTED_ORDER = {"euler": 1, "midpoint": 2, "heun": 2, "rk4": 4, "alpha0.3": 2}


@pytest.mark.parametrize("tab", ALL_FIXED, ids=lambda t: t.name)
def test_empirical_convergence_order(tab):
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    exact = jnp.array([[np.cos(1.0), -np.sin(1.0)]], jnp.float32)
    errs = []
    for K in (8, 16):
        zK = S.odeint_fixed(rot_field, z0, (0.0, 1.0), K, tab)
        errs.append(float(jnp.linalg.norm(zK - exact)))
    order = np.log2(errs[0] / errs[1])
    # f32 floors rk4 below its theoretical order; demand >= p - 0.5 with a
    # floor guard
    expected = EXPECTED_ORDER[tab.name]
    assert order > min(expected, 4) - 0.6 or errs[1] < 5e-6, (
        tab.name,
        errs,
        order,
    )


def test_fixed_trajectory_shape_and_endpoint():
    z0 = jnp.ones((4, 2), jnp.float32)
    traj = S.odeint_fixed(rot_field, z0, (0.0, 1.0), 10, S.RK4,
                          return_traj=True)
    assert traj.shape == (11, 4, 2)
    np.testing.assert_allclose(traj[0], z0)
    zT = S.odeint_fixed(rot_field, z0, (0.0, 1.0), 10, S.RK4)
    np.testing.assert_allclose(traj[-1], zT, rtol=1e-6)


def test_backward_integration_inverts_forward():
    z0 = jnp.array([[0.3, -1.2]], jnp.float32)
    z1 = S.odeint_fixed(rot_field, z0, (0.0, 1.0), 64, S.RK4)
    z0_back = S.odeint_fixed(rot_field, z1, (1.0, 0.0), 64, S.RK4)
    np.testing.assert_allclose(z0_back, z0, atol=1e-5)


# ---------------------------------------------------------------------------
# dopri5
# ---------------------------------------------------------------------------


def test_dopri5_matches_closed_form():
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    exact = jnp.array([[np.cos(1.0), -np.sin(1.0)]], jnp.float32)
    zT, nfe = S.odeint_dopri5(rot_field, z0, (0.0, 1.0), 1e-7, 1e-7)
    np.testing.assert_allclose(zT, exact, atol=1e-5)
    assert int(nfe) % 7 == 0 and int(nfe) > 0


def test_dopri5_nfe_grows_with_tolerance():
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    _, nfe_loose = S.odeint_dopri5(rot_field, z0, (0.0, 1.0), 1e-2, 1e-2)
    _, nfe_tight = S.odeint_dopri5(rot_field, z0, (0.0, 1.0), 1e-8, 1e-8)
    assert int(nfe_tight) > int(nfe_loose)


def test_dopri5_backward_direction():
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    z1, _ = S.odeint_dopri5(rot_field, z0, (0.0, 1.0), 1e-6, 1e-6)
    z0b, _ = S.odeint_dopri5(rot_field, z1, (1.0, 0.0), 1e-6, 1e-6)
    np.testing.assert_allclose(z0b, z0, atol=1e-4)


def test_dopri5_stiff_decay_stable():
    # ż = -50 z: explicit fixed-step euler K=10 explodes, dopri5 must not
    f = lambda s, z: -50.0 * z
    z0 = jnp.ones((1, 3), jnp.float32)
    zT, nfe = S.odeint_dopri5(f, z0, (0.0, 1.0), 1e-6, 1e-6)
    np.testing.assert_allclose(zT, np.exp(-50.0) * np.ones((1, 3)), atol=1e-6)


def test_dopri5_mesh_checkpoints():
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    grid = [0.0, 0.25, 0.5, 0.75, 1.0]
    mesh = S.dopri5_mesh(rot_field, z0, grid, 1e-7, 1e-7)
    assert mesh.shape == (5, 1, 2)
    for i, s in enumerate(grid):
        exact = jnp.array([[np.cos(s), -np.sin(s)]], jnp.float32)
        np.testing.assert_allclose(mesh[i], exact, atol=1e-5)


# ---------------------------------------------------------------------------
# Hypersolver stepping (Theorem 1 empirically)
# ---------------------------------------------------------------------------


def test_hyper_zero_correction_equals_base():
    z0 = jnp.ones((2, 2), jnp.float32)
    g0 = lambda e, s, z, dz: jnp.zeros_like(z)
    for tab in (S.EULER, S.HEUN):
        zh = S.odeint_hyper(rot_field, g0, z0, (0.0, 1.0), 7, tab,
                            use_kernels=False)
        zb = S.odeint_fixed(rot_field, z0, (0.0, 1.0), 7, tab)
        np.testing.assert_allclose(zh, zb, rtol=1e-6)


def test_hyper_exact_residual_kills_local_error():
    """Theorem 1: with g == exact residual, Euler's one-step error vanishes.

    For ż = Az the exact update is e^{εA} z; the Euler residual is
    R(z) = (e^{εA} − I − εA) z / ε². Supplying that R as g makes the
    hypersolved step exact to f32 precision (δ → 0 ⇒ e_k → 0).
    """
    import scipy.linalg as sla  # noqa: F401 — fallback below if missing

    eps = 0.25
    An = np.array([[0.0, 1.0], [-1.0, 0.0]])
    expA = np.eye(2) + 0.0
    # series expm (avoids scipy dependency questions): converges fast
    term = np.eye(2)
    for k in range(1, 30):
        term = term @ (An * eps) / k
        expA = expA + term
    Rmat = (expA - np.eye(2) - eps * An) / eps**2
    Rj = jnp.asarray(Rmat, jnp.float32)

    def g(e, s, z, dz):
        return z @ Rj.T

    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    K = int(1.0 / eps)
    zh = S.odeint_hyper(rot_field, g, z0, (0.0, 1.0), K, S.EULER,
                        use_kernels=False)
    exact = jnp.array([[np.cos(1.0), -np.sin(1.0)]], jnp.float32)
    assert float(jnp.linalg.norm(zh - exact)) < 1e-5
    # and plain euler at the same K is orders of magnitude worse
    ze = S.odeint_fixed(rot_field, z0, (0.0, 1.0), K, S.EULER)
    assert float(jnp.linalg.norm(ze - exact)) > 1e-2


def test_hyper_taylor_g_raises_order():
    """g = ½A²z (the Taylor ε² term) turns Euler into a 2nd-order scheme."""
    A2 = np.array([[0.0, 1.0], [-1.0, 0.0]]) @ np.array(
        [[0.0, 1.0], [-1.0, 0.0]]
    )
    Aj = jnp.asarray(0.5 * A2, jnp.float32)
    g = lambda e, s, z, dz: z @ Aj.T
    z0 = jnp.array([[1.0, 0.0]], jnp.float32)
    exact = jnp.array([[np.cos(1.0), -np.sin(1.0)]], jnp.float32)
    errs = []
    for K in (8, 16):
        zh = S.odeint_hyper(rot_field, g, z0, (0.0, 1.0), K, S.EULER,
                            use_kernels=False)
        errs.append(float(jnp.linalg.norm(zh - exact)))
    order = np.log2(errs[0] / errs[1])
    assert order > 1.6, (errs, order)


@given(alpha=st.floats(0.2, 1.0), seed=st.integers(0, 1000))
def test_alpha_family_is_second_order(alpha, seed):
    rng = np.random.default_rng(seed)
    z0 = jnp.asarray(rng.normal(size=(1, 2)), jnp.float32)
    tab = S.alpha_tableau(float(alpha))
    exact, _ = S.odeint_dopri5(rot_field, z0, (0.0, 1.0), 1e-8, 1e-8)
    err16 = float(
        jnp.linalg.norm(S.odeint_fixed(rot_field, z0, (0.0, 1.0), 16, tab) - exact)
    )
    err32 = float(
        jnp.linalg.norm(S.odeint_fixed(rot_field, z0, (0.0, 1.0), 32, tab) - exact)
    )
    if err32 > 1e-6:  # above the f32 floor
        assert np.log2(err16 / err32) > 1.5


def test_psi_matches_update():
    rng = np.random.default_rng(0)
    z0 = jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)
    eps = 0.2
    for tab in ALL_FIXED:
        direction = S.psi(rot_field, tab, 0.0, z0, eps)
        z1 = S.rk_update(rot_field, tab, 0.0, z0, eps)
        np.testing.assert_allclose(z0 + eps * direction, z1, rtol=1e-5,
                                   atol=1e-6)
