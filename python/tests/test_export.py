"""AOT export path: HLO text emission, weight JSON schema, blob round-trip,
MAC model identities."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import export as E
from compile import fields as F
from compile import macs as M
from compile import solvers as S


def test_export_fn_writes_parseable_hlo(tmp_path):
    fn = lambda x: (jnp.tanh(x @ x.T),)
    path = str(tmp_path / "t.hlo.txt")
    text = E.export_fn(fn, (jnp.ones((4, 4), jnp.float32),), path)
    assert "ENTRY" in text and "HloModule" in text
    assert os.path.getsize(path) > 100


def test_export_prints_large_constants(tmp_path):
    # regression: default HLO printing elides big constants as `{...}`,
    # which the rust-side 0.5.1 text parser turns into garbage weights
    big = jnp.asarray(np.arange(4096, dtype=np.float32).reshape(64, 64))
    fn = lambda x: (x @ big,)
    text = E.export_fn(fn, (jnp.ones((2, 64), jnp.float32),),
                       str(tmp_path / "big.hlo.txt"))
    assert "{...}" not in text
    assert "4095" in text  # the constant payload is really inline


def test_export_full_solve_hlo(tmp_path):
    params = F.init_mlp_field(jax.random.PRNGKey(0), 2, (16,), "concat")
    f = lambda s, z: F.mlp_field_apply(params, s, z, "concat")
    fn = lambda z: S.odeint_fixed(f, z, (0.0, 1.0), 4, S.HEUN)
    text = E.export_fn(fn, (jnp.ones((8, 2), jnp.float32),), str(tmp_path / "s.hlo.txt"))
    assert "while" in text  # the scan lowered to a single HLO loop


def test_export_dopri5_hlo(tmp_path):
    f = lambda s, z: -z
    fn = lambda z: S.odeint_dopri5(f, z, (0.0, 1.0), 1e-3, 1e-3)
    text = E.export_fn(fn, (jnp.ones((4, 2), jnp.float32),), str(tmp_path / "d.hlo.txt"))
    assert "while" in text


def test_write_f32_roundtrip(tmp_path):
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    meta = E.write_f32(arr, str(tmp_path / "data" / "x.bin"))
    assert meta["shape"] == [3, 4]
    back = np.fromfile(tmp_path / "data" / "x.bin", "<f4").reshape(3, 4)
    np.testing.assert_array_equal(back, arr)


def test_write_i32_roundtrip(tmp_path):
    arr = np.array([1, -2, 3], dtype=np.int32)
    E.write_i32(arr, str(tmp_path / "data" / "y.bin"))
    back = np.fromfile(tmp_path / "data" / "y.bin", "<i4")
    np.testing.assert_array_equal(back, arr)


def test_mlp_json_schema():
    layers = F.init_mlp(jax.random.PRNGKey(0), [3, 4, 2])
    j = E.mlp_json(layers)
    assert [l["act"] for l in j] == ["tanh", "id"]
    assert np.asarray(j[0]["w"]).shape == (3, 4)
    # JSON-serialisable end to end
    json.dumps(j)


def test_conv_prelu_json_schema():
    p = F.init_conv(jax.random.PRNGKey(1), 3, 8, 3)
    j = E.conv_json(p)
    assert np.asarray(j["w"]).shape == (8, 3, 3, 3)
    pr = E.prelu_json(F.init_prelu(8))
    assert len(pr["alpha"]) == 8
    json.dumps([j, pr])


# ---------------------------------------------------------------------------
# MAC model
# ---------------------------------------------------------------------------


def test_mac_identities():
    assert M.linear_macs(3, 4) == 12
    assert M.mlp_macs([2, 8, 2]) == 2 * 8 + 8 * 2
    assert M.conv_macs(1, 8, 3, 16) == 1 * 8 * 9 * 256


def test_solve_macs_hyper_overhead():
    """Relative overhead O_r = 1 + MAC_g/(p·MAC_f) shrinks with order p
    (paper §6)."""
    mac_f, mac_g = 100, 50
    for p in (1, 2, 4):
        base = M.solve_macs(mac_f, mac_g, p, 10, False)
        hyp = M.solve_macs(mac_f, mac_g, p, 10, True)
        o_r = hyp / base
        assert abs(o_r - (1 + mac_g / (p * mac_f))) < 1e-12
    o1 = M.solve_macs(mac_f, mac_g, 1, 10, True) / M.solve_macs(
        mac_f, mac_g, 1, 10, False
    )
    o4 = M.solve_macs(mac_f, mac_g, 4, 10, True) / M.solve_macs(
        mac_f, mac_g, 4, 10, False
    )
    assert o4 < o1


def test_stamp_changes_with_source(tmp_path, monkeypatch):
    from compile import aot

    s1 = aot.stamp_sources()
    assert len(s1) == 16
    s2 = aot.stamp_sources()
    assert s1 == s2  # deterministic
