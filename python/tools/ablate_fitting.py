"""Ablation: residual fitting vs trajectory fitting (paper §3.2).

The paper introduces both hypersolver objectives; §4 uses residual fitting
for CNFs/images and trajectory fitting for tracking. This tool trains BOTH
on the same small CNF and compares local residual error δ, terminal MAPE and
global trajectory error — quantifying the trade-off the paper describes
(residual fitting controls e_k, trajectory fitting controls E_k directly).

Run from python/:  python -m tools.ablate_fitting [--iters 600]
(lives outside compile/ so it never perturbs the AOT stamp)
"""

import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from compile import fields as F
from compile import solvers as S
from compile.tasks import cnf as C


def trajectory_fit(key, cnf_params, steps, iters, batch=256, lr=3e-3,
                   swap_every=100, seed=2):
    """Trajectory fitting for the CNF HyperHeun (mirrors tracking.fit_hyper
    but on the CNF field with a Heun base)."""
    hparams = C.init_hyperheun(key)
    opt = F.adamw_init(hparams)
    rng = np.random.default_rng(seed)
    f = lambda s, z: C.cnf_field(cnf_params, s, z)
    s_grid = np.linspace(C.S_SPAN[0], C.S_SPAN[1], steps + 1)

    @jax.jit
    def make_truth(z0):
        return S.dopri5_mesh(f, z0, list(s_grid), 1e-5, 1e-5)

    def loss_fn(hparams, z0, truth):
        g = lambda e, s, z, dz: C.hyper_apply(hparams, e, s, z, dz)
        traj = S.odeint_hyper(f, g, z0, C.S_SPAN, steps, S.HEUN,
                              use_kernels=False, return_traj=True)
        return jnp.mean(
            jnp.sum(jnp.linalg.norm(traj[1:] - truth[1:], axis=-1), axis=0)
        )

    @jax.jit
    def step(hparams, opt, z0, truth):
        loss, grads = jax.value_and_grad(loss_fn)(hparams, z0, truth)
        hparams, opt = F.adamw_update(grads, opt, hparams, lr,
                                      weight_decay=1e-6)
        return hparams, opt, loss

    z0 = jnp.asarray(rng.normal(size=(batch, 2)), jnp.float32)
    truth = make_truth(z0)
    loss = jnp.float32(0.0)
    for it in range(iters):
        if it > 0 and it % swap_every == 0:
            z0 = jnp.asarray(rng.normal(size=(batch, 2)), jnp.float32)
            truth = make_truth(z0)
        hparams, opt, loss = step(hparams, opt, z0, truth)
    return hparams, float(loss)


def evaluate(cnf_params, hparams, steps_eval):
    rng = np.random.default_rng(99)
    z0 = jnp.asarray(rng.normal(size=(512, 2)), jnp.float32)
    f = lambda s, z: C.cnf_field(cnf_params, s, z)
    g = lambda e, s, z, dz: C.hyper_apply(hparams, e, s, z, dz)
    s_grid = np.linspace(0.0, 1.0, steps_eval + 1)
    truth_traj = S.dopri5_mesh(f, z0, list(s_grid), 1e-6, 1e-6)
    traj = S.odeint_hyper(f, g, z0, (0.0, 1.0), steps_eval, S.HEUN,
                          use_kernels=False, return_traj=True)
    terminal_mape = float(
        jnp.mean(jnp.abs(traj[-1] - truth_traj[-1])
                 / (jnp.abs(truth_traj[-1]) + 1e-2))
    )
    global_err = float(
        jnp.mean(jnp.linalg.norm(traj - truth_traj, axis=-1))
    )
    return terminal_mape, global_err


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--density", default="rings")
    args = ap.parse_args()

    print(f"training base CNF ({args.density})...")
    cnf_params, nll = C.train_cnf(jax.random.PRNGKey(0), args.density,
                                  iters=300)
    print(f"  nll={nll:.3f}")

    print(f"residual fitting ({args.iters} iters, K=1)...")
    h_res, delta = C.fit_hyperheun(jax.random.PRNGKey(1), cnf_params,
                                   iters=args.iters)
    print(f"  delta={delta:.4f}")

    print(f"trajectory fitting ({args.iters} iters, K=4)...")
    h_traj, tloss = trajectory_fit(jax.random.PRNGKey(1), cnf_params,
                                   steps=4, iters=args.iters)
    print(f"  traj loss={tloss:.4f}")

    print(f"\n{'fit mode':<14} {'eval K':<7} {'terminal MAPE':<14} global E")
    print("-" * 50)
    for name, hp in [("residual", h_res), ("trajectory", h_traj)]:
        for k in (1, 4):
            mape, ge = evaluate(cnf_params, hp, k)
            print(f"{name:<14} {k:<7} {mape:<14.4f} {ge:.4f}")
    print(
        "\nexpected shape (paper §3.2): residual fitting wins at its "
        "training step size on terminal error; trajectory fitting wins on "
        "the along-path global error at its training K."
    )


if __name__ == "__main__":
    main()
