"""Fused linear + bias + activation Pallas kernel.

The vector-field and hypersolver MLPs are chains of ``act(x @ W + b)``.
On TPU the win is keeping the (m_blk, n_blk) output tile VMEM-resident
across the K-loop and applying bias + activation in the epilogue, so the
pre-activation never round-trips HBM. The BlockSpecs below express exactly
that schedule; ``interpret=True`` makes the same program runnable on CPU
PJRT (Mosaic custom-calls only execute on real TPUs).

VMEM budget (f32): m_blk*k_blk + k_blk*n_blk + m_blk*n_blk floats. With the
default 128³ tiling that is 3 × 64 KiB = 192 KiB ≪ 16 MiB VMEM, leaving room
for double-buffering the x/w input streams (the TPU pallas default).
MXU: a 128×128×128 f32 tile fully occupies the systolic array per grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import act, linear_act_ref


def _linear_act_kernel(x_ref, w_ref, b_ref, o_ref, *, kind, k_steps):
    """One (i, j, k) grid step of the tiled matmul.

    The output tile doubles as the f32 accumulator: initialised at k == 0,
    accumulated over the K-loop, bias + activation applied in the epilogue
    on the final K step. Grid iteration order is row-major, so for a fixed
    (i, j) the k axis is innermost and the tile stays resident.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        o_ref[...] = act(o_ref[...] + b_ref[...][None, :], kind)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (keeps the grid exact)."""
    blk = min(dim, target)
    while dim % blk != 0:
        blk -= 1
    return blk


@functools.partial(jax.jit, static_argnames=("kind",))
def fused_linear_act(x, w, b, kind: str = "tanh"):
    """act(x @ w + b) with a VMEM-tiled Pallas matmul.

    Shapes: x (m, k), w (k, n), b (n,) → (m, n). Falls back to the jnp
    oracle when the problem is too small for tiling to be meaningful
    (kernel launch overhead would dominate on TPU, and the interpreter is
    pure overhead on CPU).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)

    if m * n * k < 8192:  # tiny problem: the oracle is the right dispatch
        return linear_act_ref(x, w, b, kind)

    m_blk = _pick_block(m, 128)
    n_blk = _pick_block(n, 128)
    k_blk = _pick_block(k, 128)
    k_steps = k // k_blk
    grid = (m // m_blk, n // n_blk, k_steps)

    kernel = functools.partial(_linear_act_kernel, kind=kind, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m_blk, k_blk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((k_blk, n_blk), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((n_blk,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((m_blk, n_blk), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)
