"""Layer-1 Pallas kernels for hypersolver inference.

Every kernel here runs with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the interpreter path lowers the kernels to
plain HLO ops that any backend (including the rust PJRT CPU client) can run.
On a real TPU the same BlockSpecs map tiles into VMEM and matmuls onto the
MXU; see DESIGN.md §4 (Hardware adaptation) for the footprint estimates.

Kernels:
  - ``linear_act.fused_linear_act`` — act(x @ W + b), one VMEM pass.
  - ``hyper_step.hyper_step``       — z + eps*psi + eps^{p+1}*g, fused.
  - ``rk_combine.rk_combine``       — z + eps * sum_i b_i r_i.

``ref.py`` carries pure-jnp oracles; pytest + hypothesis sweep shapes and
dtypes and assert_allclose against them.
"""

from compile.kernels.linear_act import fused_linear_act
from compile.kernels.hyper_step import hyper_step
from compile.kernels.rk_combine import rk_combine

__all__ = ["fused_linear_act", "hyper_step", "rk_combine"]
