"""Fused Runge-Kutta stage combination kernel: z + eps * sum_i b_i r_i.

The final line of eq. (3): after the p stage derivatives r_i are computed
the solver combines them with the tableau weights b. For p stages this is
p fused multiply-adds per element; doing it in one VPU pass reads each
stage once instead of materialising p-1 partial sums in HBM.

The stage count p is a compile-time constant (it is part of the solver
identity, like the step size), so the combination loop is unrolled inside
the kernel body.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import rk_combine_ref


def _rk_combine_kernel(z_ref, stages_ref, o_ref, *, b, eps):
    acc = z_ref[...]
    for i, bi in enumerate(b):  # p is static: unrolled FMA chain
        if bi != 0.0:
            acc = acc + (eps * bi) * stages_ref[i, :]
    o_ref[...] = acc


def _pick_block(dim: int, target: int) -> int:
    blk = min(dim, target)
    while dim % blk != 0:
        blk -= 1
    return blk


def rk_combine(z, stages, b, eps):
    """z + eps * Σ_i b_i stages_i (tableau output combination).

    z: state, stages: (p, *z.shape), b: tuple/list of p python floats,
    eps: python float. b and eps are baked at trace time.
    """
    b = tuple(float(x) for x in b)
    eps = float(eps)
    p = stages.shape[0]
    assert p == len(b), (p, b)
    shape = z.shape
    flat = z.size
    if flat < 1024:
        return rk_combine_ref(z, stages, jnp.array(b, jnp.float32), eps)

    blk = _pick_block(flat, 1024)
    grid = (flat // blk,)
    kernel = functools.partial(_rk_combine_kernel, b=b, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((p, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((flat,), jnp.float32),
        interpret=True,
    )(z.reshape(flat), stages.reshape(p, flat))
    return out.reshape(shape)
