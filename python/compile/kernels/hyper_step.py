"""Fused hypersolver update kernel: z + eps*psi + eps^{p+1}*g.

Eq. (5) of the paper. Naively this is two multiplies and two adds over
three same-shaped arrays — XLA on CPU fuses it anyway, but on TPU keeping
it a single VPU pass guarantees z/psi/g are each read from HBM exactly once
and z' written once (arithmetic intensity 4 flops / 16 bytes: pure
bandwidth). The kernel is 1-D over the flattened state so it serves every
task (2-D CNF states, conv image states, tracking states) unchanged.

VMEM: 4 blocks × blk floats; blk = 1024 → 16 KiB. Bandwidth-bound by
design; the MXU is idle (this is the paper's point — the correction term
costs one g_ω evaluation, and the state update itself is negligible).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import hyper_step_ref


def _hyper_step_kernel(z_ref, psi_ref, g_ref, o_ref, *, eps, order):
    scale = eps ** (order + 1)
    o_ref[...] = z_ref[...] + eps * psi_ref[...] + scale * g_ref[...]


def _pick_block(dim: int, target: int) -> int:
    blk = min(dim, target)
    while dim % blk != 0:
        blk -= 1
    return blk


def hyper_step(z, psi, g, eps, order: int = 1):
    """Hypersolved state update (eq. 5).

    z, psi, g: same shape; eps: python float or 0-d array; order: base
    solver order p. Returns z + eps*psi + eps^{p+1}*g.

    ``eps`` must be a concrete float at trace time (it is baked into the
    kernel — the AOT artifacts are per-(solver, K) anyway, so the step size
    is a compile-time constant on the request path).
    """
    eps = float(eps)
    shape = z.shape
    flat = z.size
    if flat < 1024:  # oracle dispatch for tiny states
        return hyper_step_ref(z, psi, g, eps, order)

    blk = _pick_block(flat, 1024)
    grid = (flat // blk,)
    kernel = functools.partial(_hyper_step_kernel, eps=eps, order=order)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))] * 3,
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((flat,), jnp.float32),
        interpret=True,
    )(z.reshape(flat), psi.reshape(flat), g.reshape(flat))
    return out.reshape(shape)
