"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (pytest +
hypothesis sweeps in ``python/tests/test_kernels.py``). They are also used
directly by the JAX layer when a shape is too small / ragged to be worth a
kernel launch (the dispatch heuristics live in the kernel modules).
"""

import jax.numpy as jnp


def act(x, kind: str):
    """Activation dispatch shared by kernel and oracle."""
    if kind == "id":
        return x
    if kind == "tanh":
        return jnp.tanh(x)
    if kind == "softplus":
        return jnp.logaddexp(x, 0.0)
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    raise ValueError(f"unknown activation {kind!r}")


def linear_act_ref(x, w, b, kind: str = "tanh"):
    """act(x @ w + b) — oracle for fused_linear_act.

    x: (m, k), w: (k, n), b: (n,)  →  (m, n)
    """
    return act(jnp.dot(x, w) + b[None, :], kind)


def hyper_step_ref(z, psi, g, eps, order: int):
    """z + eps*psi + eps^{p+1}*g — oracle for hyper_step.

    The hypersolved update of eq. (5) in the paper: ``psi`` is the base
    solver's update direction, ``g`` the hypersolver net output, ``order``
    the base solver order p.
    """
    return z + eps * psi + (eps ** (order + 1)) * g


def rk_combine_ref(z, stages, b, eps):
    """z + eps * sum_i b_i stages_i — oracle for rk_combine.

    stages: (p, *z.shape) stacked RK stage derivatives, b: (p,) tableau
    weights.
    """
    acc = jnp.tensordot(b, stages, axes=1)
    return z + eps * acc
