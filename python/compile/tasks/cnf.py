"""Continuous normalizing flows on 2-D toy densities (paper §4.2, Figs 1/7).

FFJORD-style CNF with *exact* trace (2-D Jacobian: two jvp's per field
evaluation, no Hutchinson noise needed at this dimension). Training follows
Grathwohl et al.: maximize data log-likelihood by integrating the augmented
state [z, Δlogp] backward from the data (s=1) to the base (s=0).

After the CNF is trained, a second-order Heun hypersolver (HyperHeun) is
fitted by residual fitting with K=1 on sampling-direction (0 → 1)
trajectories against dopri5 at tol 1e-5 — the paper's headline "2-NFE CNF
sampling" configuration.

Densities: pinwheel, rings, checkerboard, and the modified `circles` with
three connecting curves (paper §C.3).
"""

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from compile import fields as F
from compile import solvers as S

DENSITIES = ("pinwheel", "rings", "checkerboard", "circles")

CNF_HIDDEN = (64, 64, 64)  # paper: 128³ on GPU; 64³ at 1-core CPU budget
HYPER_HIDDEN = (64, 64)  # "two-layer ... Heun hypersolvers" (§4.2)
S_SPAN = (0.0, 1.0)


# ---------------------------------------------------------------------------
# Density samplers (numpy, deterministic under the passed Generator)
# ---------------------------------------------------------------------------


def sample_density(name: str, n: int, rng: np.random.Generator) -> np.ndarray:
    """Draw n samples from a named 2-D toy density, roughly in [-3, 3]²."""
    if name == "pinwheel":
        radial_std, tangential_std, num_classes, rate = 0.3, 0.1, 5, 0.25
        labels = rng.integers(0, num_classes, n)
        feats = rng.normal(size=(n, 2)) * np.array(
            [radial_std, tangential_std]
        ) + np.array([1.0, 0.0])
        angles = 2 * np.pi * labels / num_classes + rate * np.exp(
            feats[:, 0]
        )
        rot = np.stack(
            [
                np.stack([np.cos(angles), -np.sin(angles)], -1),
                np.stack([np.sin(angles), np.cos(angles)], -1),
            ],
            -2,
        )
        return 2.0 * np.einsum("ni,nij->nj", feats, rot).astype(np.float32)
    if name == "rings":
        radii = np.array([1.0, 2.0, 3.0])
        idx = rng.integers(0, len(radii), n)
        ang = rng.uniform(0, 2 * np.pi, n)
        r = radii[idx] + rng.normal(scale=0.08, size=n)
        return np.stack([r * np.cos(ang), r * np.sin(ang)], -1).astype(
            np.float32
        )
    if name == "checkerboard":
        x1 = rng.uniform(-3, 3, n)
        x2_ = rng.uniform(0, 1.5, n)
        offs = (np.floor(x1 / 1.5) % 2) * 1.5
        x2 = x2_ + offs - 1.5 * rng.integers(0, 2, n) * 2
        return np.stack([x1, x2], -1).astype(np.float32)
    if name == "circles":
        # two annuli connected by three radial curves (paper's modified,
        # "more challenging" variant)
        kind = rng.uniform(0, 1, n)
        ang = rng.uniform(0, 2 * np.pi, n)
        out = np.empty((n, 2))
        inner = kind < 0.4
        outerm = (kind >= 0.4) & (kind < 0.8)
        curves = kind >= 0.8
        r_in = 1.0 + rng.normal(scale=0.06, size=n)
        r_out = 2.5 + rng.normal(scale=0.06, size=n)
        out[inner] = np.stack(
            [r_in[inner] * np.cos(ang[inner]), r_in[inner] * np.sin(ang[inner])],
            -1,
        )
        out[outerm] = np.stack(
            [
                r_out[outerm] * np.cos(ang[outerm]),
                r_out[outerm] * np.sin(ang[outerm]),
            ],
            -1,
        )
        # connectors at angles 0, 2π/3, 4π/3
        ci = rng.integers(0, 3, n)
        base_ang = 2 * np.pi * ci / 3 + rng.normal(scale=0.05, size=n)
        rr = rng.uniform(1.0, 2.5, n)
        conn = np.stack([rr * np.cos(base_ang), rr * np.sin(base_ang)], -1)
        out[curves] = conn[curves]
        return out.astype(np.float32)
    raise KeyError(f"unknown density {name!r}")


# ---------------------------------------------------------------------------
# CNF model
# ---------------------------------------------------------------------------


def init_cnf(key) -> Dict:
    return F.init_mlp_field(key, 2, CNF_HIDDEN, time_mode="concat")


def cnf_field(params, s, z, use_kernels: bool = False):
    """v(s, z): the flow's velocity field on (B, 2) states."""
    return F.mlp_field_apply(params, s, z, "concat", use_kernels)


def aug_field(params, s, u):
    """Augmented dynamics on u = [z (2), Δlogp (1)]: [v, -tr ∂v/∂z].

    Exact trace with two jvp's (2-D state).
    """
    z = u[:, :2]

    def vfun(zz):
        return cnf_field(params, s, zz)

    e1 = jnp.broadcast_to(jnp.array([1.0, 0.0], jnp.float32), z.shape)
    e2 = jnp.broadcast_to(jnp.array([0.0, 1.0], jnp.float32), z.shape)
    v, j1 = jax.jvp(vfun, (z,), (e1,))
    _, j2 = jax.jvp(vfun, (z,), (e2,))
    tr = j1[:, 0] + j2[:, 1]
    return jnp.concatenate([v, -tr[:, None]], axis=1)


def log_prob_base(z):
    """Standard normal base density."""
    return -0.5 * jnp.sum(z**2, axis=1) - z.shape[1] * 0.5 * jnp.log(
        2 * jnp.pi
    )


def nll_loss(params, x, steps: int = 8):
    """-E[log p(x)] via backward rk4 integration of the augmented state."""
    u1 = jnp.concatenate([x, jnp.zeros((x.shape[0], 1), jnp.float32)], axis=1)
    u0 = S.odeint_fixed(
        lambda s, u: aug_field(params, s, u), u1, (1.0, 0.0), steps, S.RK4
    )
    z0, l0 = u0[:, :2], u0[:, 2]
    logp = log_prob_base(z0) - l0
    return -jnp.mean(logp)


def train_cnf(key, density: str, iters: int = 600, batch: int = 256,
              lr: float = 1e-3, seed: int = 0):
    """Train one CNF; returns (params, final_nll)."""
    params = init_cnf(key)
    opt = F.adamw_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, opt, x):
        loss, grads = jax.value_and_grad(nll_loss)(params, x)
        params, opt = F.adamw_update(grads, opt, params, lr)
        return params, opt, loss

    loss = jnp.float32(0.0)
    for it in range(iters):
        x = jnp.asarray(sample_density(density, batch, rng))
        params, opt, loss = step(params, opt, x)
    return params, float(loss)


# ---------------------------------------------------------------------------
# HyperHeun fitting (sampling direction, K=1 residuals — paper §4.2)
# ---------------------------------------------------------------------------


def init_hyperheun(key) -> Dict:
    return F.init_hyper_mlp(key, 2, HYPER_HIDDEN)


def hyper_apply(hparams, eps, s, z, dz, use_kernels: bool = False):
    return F.hyper_mlp_apply(hparams, eps, s, z, dz, use_kernels)


def residual_loss(hparams, cnf_params, z0, z1, tab: S.Tableau):
    """‖R − g_ω‖ for the K=1 mesh {0, 1} (eq. 6), sampling direction."""
    eps = S_SPAN[1] - S_SPAN[0]
    f = lambda s, z: cnf_field(cnf_params, s, z)
    direction = S.psi(f, tab, S_SPAN[0], z0, eps)
    resid = (z1 - z0 - eps * direction) / eps ** (tab.order + 1)
    dz = f(S_SPAN[0], z0)
    pred = hyper_apply(hparams, eps, S_SPAN[0], z0, dz)
    return jnp.mean(jnp.linalg.norm(resid - pred, axis=1))


def fit_hyperheun(
    key,
    cnf_params,
    iters: int = 800,
    batch: int = 256,
    lr: float = 5e-3,
    swap_every: int = 100,
    seed: int = 1,
):
    """Two-stage residual fitting (paper §C.3: batch swapped every 100 it).

    Ground truth z(1) from dopri5 at tol 1e-5 on the sampling direction.
    Returns (hyper_params, final residual loss δ).
    """
    hparams = init_hyperheun(key)
    opt = F.adamw_init(hparams)
    rng = np.random.default_rng(seed)
    f = lambda s, z: cnf_field(cnf_params, s, z)

    @jax.jit
    def truth(z0):
        z1, _ = S.odeint_dopri5(f, z0, S_SPAN, 1e-5, 1e-5)
        return z1

    @jax.jit
    def step(hparams, opt, z0, z1):
        loss, grads = jax.value_and_grad(residual_loss)(
            hparams, cnf_params, z0, z1, S.HEUN
        )
        hparams, opt = F.adamw_update(
            grads, opt, hparams, lr, weight_decay=1e-6
        )
        return hparams, opt, loss

    z0 = jnp.asarray(rng.normal(size=(batch, 2)), jnp.float32)
    z1 = truth(z0)
    loss = jnp.float32(0.0)
    for it in range(iters):
        if it > 0 and it % swap_every == 0:
            z0 = jnp.asarray(rng.normal(size=(batch, 2)), jnp.float32)
            z1 = truth(z0)
        hparams, opt, loss = step(hparams, opt, z0, z1)
    return hparams, float(loss)
