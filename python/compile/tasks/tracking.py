"""Trajectory tracking with a Galerkin-style Neural ODE (paper §C.1, Fig 8).

A depth-varying MLP field (truncated Fourier basis in s — the Galerkin
flavour of Massaroli et al. 2020b) is trained with an integral loss to track
the periodic signal β(s) = [sin 2πs, cos 2πs] over S = [0, 1]. A three-layer
HyperEuler (hidden 64, 64, 64) is then fitted with **trajectory fitting**
(the global-truncation-error loss of §3.2), the experiment that Fig. 8's
E_k-vs-NFE pareto front evaluates.
"""

from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from compile import fields as F
from compile import solvers as S

STATE_DIM = 2
FIELD_HIDDEN = (64, 64)
HYPER_HIDDEN = (64, 64, 64)  # "three-layer ... hidden dimensions 64,64,64"
S_SPAN = (0.0, 1.0)
LOSS_MESH = 20  # mesh for the integral tracking loss


def beta(s):
    """Reference periodic signal to track."""
    ang = 2 * jnp.pi * jnp.asarray(s, jnp.float32)
    return jnp.stack([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_field(key) -> Dict:
    return F.init_mlp_field(key, STATE_DIM, FIELD_HIDDEN, time_mode="fourier3")


def field(params, s, z):
    return F.mlp_field_apply(params, s, z, "fourier3")


def tracking_loss(params, z0, steps: int = LOSS_MESH):
    """∫ ||z(s) − β(s)||² ds approximated on a uniform mesh (rk4)."""
    traj = S.odeint_fixed(
        lambda s, z: field(params, s, z), z0, S_SPAN, steps, S.RK4,
        return_traj=True,
    )
    s_grid = jnp.linspace(S_SPAN[0], S_SPAN[1], steps + 1)
    target = beta(s_grid)[:, None, :]  # (K+1, 1, 2)
    return jnp.mean(jnp.sum((traj - target) ** 2, axis=-1))


def train_tracker(key, iters: int = 400, batch: int = 64, lr: float = 3e-3,
                  seed: int = 0):
    """Train the tracking Neural ODE from initial states near β(0)."""
    params = init_field(key)
    opt = F.adamw_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, opt, z0):
        loss, grads = jax.value_and_grad(tracking_loss)(params, z0)
        params, opt = F.adamw_update(grads, opt, params, lr)
        return params, opt, loss

    loss = jnp.float32(0.0)
    for _ in range(iters):
        z0 = jnp.asarray(
            beta(0.0)[None, :] + 0.3 * rng.normal(size=(batch, STATE_DIM)),
            jnp.float32,
        )
        params, opt, loss = step(params, opt, z0)
    return params, float(loss)


# ---------------------------------------------------------------------------
# HyperEuler via trajectory fitting (§3.2 "Trajectory fitting")
# ---------------------------------------------------------------------------


def init_hyper(key) -> Dict:
    return F.init_hyper_mlp(key, STATE_DIM, HYPER_HIDDEN)


def hyper_apply(hparams, eps, s, z, dz):
    return F.hyper_mlp_apply(hparams, eps, s, z, dz)


def trajectory_loss(hparams, params, z0, truth_traj, steps: int):
    """Σ_k ||z(s_k) − z_k||₂ with z_k rolled out by the hypersolved Euler."""
    f = lambda s, z: field(params, s, z)
    g = lambda e, s, z, dz: hyper_apply(hparams, e, s, z, dz)
    traj = S.odeint_hyper(
        f, g, z0, S_SPAN, steps, S.EULER, use_kernels=False, return_traj=True
    )
    d = traj[1:] - truth_traj[1:]
    return jnp.mean(
        jnp.sum(jnp.linalg.norm(d, axis=-1), axis=0)
    )


def fit_hyper(
    key,
    params,
    steps: int = 10,
    iters: int = 600,
    batch: int = 64,
    lr: float = 3e-3,
    swap_every: int = 50,
    seed: int = 1,
):
    """Trajectory fitting against dopri5(1e-5) checkpoints on a K-step mesh.

    Minimises the *global* truncation error directly (rollout through the
    hypersolved scheme, gradients through all K steps).
    """
    hparams = init_hyper(key)
    opt = F.adamw_init(hparams)
    rng = np.random.default_rng(seed)
    s_grid = np.linspace(S_SPAN[0], S_SPAN[1], steps + 1)
    f = lambda s, z: field(params, s, z)

    @jax.jit
    def make_truth(z0):
        return S.dopri5_mesh(f, z0, list(s_grid), 1e-5, 1e-5)

    @jax.jit
    def step_fn(hparams, opt, z0, truth):
        loss, grads = jax.value_and_grad(trajectory_loss)(
            hparams, params, z0, truth, steps
        )
        hparams, opt = F.adamw_update(grads, opt, hparams, lr)
        return hparams, opt, loss

    def draw(n):
        return jnp.asarray(
            beta(0.0)[None, :] + 0.3 * rng.normal(size=(n, STATE_DIM)),
            jnp.float32,
        )

    z0 = draw(batch)
    truth = make_truth(z0)
    loss = jnp.float32(0.0)
    for it in range(iters):
        if it > 0 and it % swap_every == 0:
            z0 = draw(batch)
            truth = make_truth(z0)
        hparams, opt, loss = step_fn(hparams, opt, z0, truth)
    return hparams, float(loss)
