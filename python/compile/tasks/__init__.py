"""Benchmark tasks: CNF density estimation, image classification, tracking."""
