"""Image classification with convolutional Neural ODEs (paper §4.1).

Substitution (DESIGN.md §3): MNIST/CIFAR10 are replaced by procedurally
generated datasets — the offline image has no dataset downloads, and the
paper's claims are about ODE-solution accuracy vs compute, not image
content. Classes are parametric stroke patterns (grayscale, "smnist") and
colored textured strokes ("scifar"), 16×16, 10 classes, with per-sample
jitter/noise so the classification task is non-trivial.

Model shape follows appendix C.2 at CPU-friendly widths: input-layer
augmentation (conv in→aug), DepthCat conv field, conv+linear head. The
HyperEuler g_ω is the appendix's 2-layer PReLU CNN taking cat(z, f(z), s).
"""

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from compile import fields as F
from compile import solvers as S

# CPU-budget widths (paper: 28×28/32×32, aug 12/8, hidden 64; see DESIGN.md
# §3 — the MAC_g/MAC_f ratio ≈ 0.5 of the paper is preserved).
HW = 16
N_CLASSES = 10
AUG_CH = 6
HIDDEN_CH = 16
HYPER_CH = 16
S_SPAN = (0.0, 1.0)

DATASETS = {"smnist": 1, "scifar": 3}  # name -> channels


# ---------------------------------------------------------------------------
# Synthetic dataset
# ---------------------------------------------------------------------------


def _render_stroke(c: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 16×16 grayscale sample of class c.

    Class identity = (start angle, curvature, n_lobes) of a parametric
    curve; gaussian bumps are splatted along it. Per-sample jitter: center
    shift, rotation, amplitude noise.
    """
    t = np.linspace(0.0, 1.0, 24)
    ang0 = 2 * np.pi * c / N_CLASSES + rng.normal(scale=0.1)
    curv = 2.0 + 1.5 * ((c * 7) % N_CLASSES) / N_CLASSES
    lobes = 1 + (c % 3)
    r = 0.25 + 0.18 * np.sin(lobes * 2 * np.pi * t)
    ang = ang0 + curv * t
    cx = 0.5 + 0.06 * rng.normal()
    cy = 0.5 + 0.06 * rng.normal()
    px = cx + r * np.cos(ang)
    py = cy + r * np.sin(ang)
    ys, xs = np.meshgrid(
        np.linspace(0, 1, HW), np.linspace(0, 1, HW), indexing="ij"
    )
    img = np.zeros((HW, HW))
    sig2 = 2 * (0.045**2)
    for x, y in zip(px, py):
        img += np.exp(-((xs - x) ** 2 + (ys - y) ** 2) / sig2)
    img = img / (img.max() + 1e-6)
    img += rng.normal(scale=0.05, size=img.shape)
    return img.astype(np.float32)


def make_dataset(
    name: str, n: int, rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray]:
    """(images NCHW f32, labels int32). 'scifar' adds class-coded color +
    background texture over the stroke pattern."""
    ch = DATASETS[name]
    labels = rng.integers(0, N_CLASSES, n).astype(np.int32)
    imgs = np.zeros((n, ch, HW, HW), np.float32)
    for i, c in enumerate(labels):
        g = _render_stroke(int(c), rng)
        if ch == 1:
            imgs[i, 0] = g
        else:
            # class-dependent color mixing + low-freq background texture
            mix = np.array(
                [
                    0.3 + 0.7 * ((c * 3) % 10) / 10,
                    0.3 + 0.7 * ((c * 7 + 2) % 10) / 10,
                    0.3 + 0.7 * ((c * 5 + 5) % 10) / 10,
                ]
            )
            ys, xs = np.meshgrid(
                np.linspace(0, 2 * np.pi, HW),
                np.linspace(0, 2 * np.pi, HW),
                indexing="ij",
            )
            tex = 0.15 * np.sin(xs * (1 + c % 4) + ys * (1 + (c // 4)))
            for k in range(3):
                imgs[i, k] = mix[k] * g + tex + rng.normal(
                    scale=0.05, size=g.shape
                )
    return imgs, labels


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def init_model(key, name: str) -> Dict:
    return F.init_image_model(
        key, DATASETS[name], AUG_CH, HIDDEN_CH, HW, N_CLASSES
    )


def field(params, s, z):
    return F.conv_field_apply(params["field"], s, z)


def classify(params, x_img, steps: int, tab: S.Tableau):
    """Full forward pass: h_x -> odeint -> h_y (logits)."""
    z0 = F.image_hx_apply(params, x_img)
    zT = S.odeint_fixed(
        lambda s, z: field(params, s, z), z0, S_SPAN, steps, tab
    )
    return F.image_hy_apply(params, zT)


def classify_hyper(params, hparams, x_img, steps: int, tab: S.Tableau):
    """Forward pass with a hypersolved ODE block."""
    z0 = F.image_hx_apply(params, x_img)
    g = lambda e, s, z, dz: F.hyper_cnn_apply(hparams, e, s, z, dz)
    zT = S.odeint_hyper(
        lambda s, z: field(params, s, z), g, z0, S_SPAN, steps, tab,
        use_kernels=False,
    )
    return F.image_hy_apply(params, zT)


def ce_loss(params, x_img, labels, steps: int, tab: S.Tableau):
    logits = classify(params, x_img, steps, tab)
    logz = jax.scipy.special.logsumexp(logits, axis=1)
    ll = logits[jnp.arange(labels.shape[0]), labels] - logz
    return -jnp.mean(ll)


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, axis=1) == labels))


def train_model(
    key,
    name: str,
    iters: int = 250,
    batch: int = 32,
    lr: float = 3e-3,
    seed: int = 0,
    train_steps: int = 2,
    train_tab: S.Tableau = S.MIDPOINT,
):
    """Train a conv Neural ODE classifier (midpoint, K=train_steps).

    The paper trains with dopri5; a fixed low-order solver at training time
    is a CPU budget substitution — the trained dynamics are equally 'real'
    for the hypersolver experiments, which only need a trained f_θ.
    """
    params = init_model(key, name)
    opt = F.adamw_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(params, opt, x, y, lr_now):
        loss, grads = jax.value_and_grad(ce_loss)(
            params, x, y, train_steps, train_tab
        )
        params, opt = F.adamw_update(grads, opt, params, lr_now)
        return params, opt, loss

    loss = jnp.float32(0.0)
    for it in range(iters):
        x, y = make_dataset(name, batch, rng)
        lr_now = F.cosine_lr(jnp.int32(it), iters, lr, 1e-4)
        params, opt, loss = step(
            params, opt, jnp.asarray(x), jnp.asarray(y), lr_now
        )
    return params, float(loss)


# ---------------------------------------------------------------------------
# Hypersolver fitting (residual fitting on K=10 dopri5 meshes — §4.1)
# ---------------------------------------------------------------------------


def init_hyper(key) -> Dict:
    return F.init_hyper_cnn(key, AUG_CH, HYPER_CH)


def residual_loss_mesh(hparams, params, mesh, s_grid, tab: S.Tableau):
    """Mean ‖R_k − g_ω(...)‖ over a K-step mesh (eq. 6).

    mesh: (K+1, B, C, H, W) dopri5 checkpoints of the conv state.
    """
    eps = float(s_grid[1] - s_grid[0])
    f = lambda s, z: field(params, s, z)
    total = 0.0
    K = mesh.shape[0] - 1
    for k in range(K):
        zk, zk1 = mesh[k], mesh[k + 1]
        s = float(s_grid[k])
        direction = S.psi(f, tab, s, zk, eps)
        resid = (zk1 - zk - eps * direction) / eps ** (tab.order + 1)
        dz = f(s, zk)
        pred = F.hyper_cnn_apply(hparams, eps, s, zk, dz)
        d = (resid - pred).reshape(zk.shape[0], -1)
        total = total + jnp.mean(jnp.linalg.norm(d, axis=1))
    return total / K


def fit_hyper(
    key,
    params,
    name: str,
    tab: S.Tableau = S.EULER,
    mesh_k: int = 10,
    iters: int = 500,
    batch: int = 32,
    lr: float = 1e-2,
    swap_every: int = 10,
    pretrain: int = 10,
    seed: int = 1,
    tol: float = 1e-4,
):
    """Two-phase residual fitting (paper §C.2).

    Phase 1: ``pretrain`` iterations on a single batch's trajectories.
    Phase 2: swap the residual-generating batch every ``swap_every``
    iterations. Ground truth: dopri5 tol=1e-4 meshes with K=mesh_k.
    Returns (hyper_params, final δ).
    """
    hparams = init_hyper(key)
    opt = F.adamw_init(hparams)
    rng = np.random.default_rng(seed)
    s_grid = np.linspace(S_SPAN[0], S_SPAN[1], mesh_k + 1)
    f = lambda s, z: field(params, s, z)

    @jax.jit
    def make_mesh(x):
        z0 = F.image_hx_apply(params, x)
        return S.dopri5_mesh(f, z0, list(s_grid), tol, tol)

    @jax.jit
    def step(hparams, opt, mesh, lr_now):
        loss, grads = jax.value_and_grad(residual_loss_mesh)(
            hparams, params, mesh, s_grid, tab
        )
        hparams, opt = F.adamw_update(grads, opt, hparams, lr_now)
        return hparams, opt, loss

    x, _ = make_dataset(name, batch, rng)
    mesh = make_mesh(jnp.asarray(x))
    loss = jnp.float32(0.0)
    for it in range(iters):
        if it >= pretrain and (it - pretrain) % swap_every == 0:
            x, _ = make_dataset(name, batch, rng)
            mesh = make_mesh(jnp.asarray(x))
        lr_now = F.cosine_lr(jnp.int32(it), iters, lr, 5e-4)
        hparams, opt, loss = step(hparams, opt, mesh, lr_now)
    return hparams, float(loss)
