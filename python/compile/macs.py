"""Analytic multiply-accumulate (MAC) cost model.

The paper reports algorithmic complexity in MACs (§4.1) because NFE alone
ignores the hypersolver overhead MAC_g. These counts are *per sample* (batch
size excluded) and are exported to the manifest so the rust coordinator and
benches account costs identically to the python layer.

Totals for a solve: fixed p-stage solver over K steps costs p·K·MAC_f;
a hypersolved variant adds K·MAC_g (one g_ω evaluation per step — eq. §6's
relative overhead O_r = 1 + MAC_g / (p·MAC_f)).
"""

from typing import Dict, List, Sequence


def linear_macs(n_in: int, n_out: int) -> int:
    return n_in * n_out


def mlp_macs(sizes: Sequence[int]) -> int:
    return sum(a * b for a, b in zip(sizes[:-1], sizes[1:]))


def conv_macs(c_in: int, c_out: int, ksize: int, hw: int) -> int:
    return c_in * c_out * ksize * ksize * hw * hw


def mlp_field_macs(state_dim: int, hidden: Sequence[int], feat_dim: int) -> int:
    return mlp_macs([state_dim + feat_dim, *hidden, state_dim])


def hyper_mlp_macs(state_dim: int, hidden: Sequence[int]) -> int:
    return mlp_macs([2 * state_dim + 2, *hidden, state_dim])


def conv_field_macs(aug_ch: int, hidden_ch: int, hw: int) -> int:
    return (
        conv_macs(aug_ch + 1, hidden_ch, 3, hw)
        + conv_macs(hidden_ch + 1, hidden_ch, 3, hw)
        + conv_macs(hidden_ch, aug_ch, 3, hw)
    )


def hyper_cnn_macs(aug_ch: int, hidden_ch: int, hw: int) -> int:
    return conv_macs(2 * aug_ch + 1, hidden_ch, 3, hw) + conv_macs(
        hidden_ch, aug_ch, 3, hw
    )


def solve_macs(mac_f: int, mac_g: int, stages: int, steps: int,
               hyper: bool) -> int:
    """Total MACs of one fixed-step solve (per sample)."""
    total = stages * steps * mac_f
    if hyper:
        total += steps * mac_g
    return total
