"""AOT export utilities: jax → HLO text, weights → JSON, raw f32 blobs.

HLO **text** is the interchange format (NOT serialized HloModuleProto):
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 rust crate links) rejects
with ``proto.id() <= INT_MAX``. The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.
"""

import json
import os
from typing import Any, Dict, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Lowered jax computation → XLA HLO text (tuple-rooted).

    ``print_large_constants=True`` is essential: the default printer elides
    big dense constants as ``constant({...})``, which the 0.5.1 text parser
    silently turns into garbage — the trained weights ARE large constants in
    the full-solve exports.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def export_fn(fn, example_args, path: str) -> str:
    """jit-lower ``fn`` at the example shapes and write HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return text


def _np(x) -> Any:
    """jnp/np array → nested python lists for JSON."""
    return np.asarray(x).astype(np.float32).round(7).tolist()


def linear_json(p: Dict, act: str) -> Dict:
    return {"kind": "linear", "w": _np(p["w"]), "b": _np(p["b"]), "act": act}


def mlp_json(layers, hidden_act: str = "tanh", out_act: str = "id") -> list:
    out = []
    for i, p in enumerate(layers):
        act = hidden_act if i < len(layers) - 1 else out_act
        out.append(linear_json(p, act))
    return out


def conv_json(p: Dict) -> Dict:
    # OIHW weights; SAME padding, stride 1 everywhere in this codebase.
    return {"kind": "conv2d", "w": _np(p["w"]), "b": _np(p["b"])}


def prelu_json(p: Dict) -> Dict:
    return {"kind": "prelu", "alpha": _np(p["alpha"])}


def write_json(obj: Dict, path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f)


def write_f32(arr, path: str) -> Dict:
    """Raw little-endian f32 blob + shape descriptor for the manifest."""
    a = np.ascontiguousarray(np.asarray(arr), dtype="<f4")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    a.tofile(path)
    return {"path": os.path.basename(os.path.dirname(path)) + "/" + os.path.basename(path), "shape": list(a.shape)}


def write_i32(arr, path: str) -> Dict:
    a = np.ascontiguousarray(np.asarray(arr), dtype="<i4")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    a.tofile(path)
    return {"path": os.path.basename(os.path.dirname(path)) + "/" + os.path.basename(path), "shape": list(a.shape)}
