"""Explicit ODE solvers for Neural ODE inference (Layer 2).

Implements the solver zoo of the paper as Butcher tableaus (eq. 3 / Fig. 5):
euler, midpoint, heun, RK4, the second-order alpha family, and the adaptive
Dormand-Prince 5(4) pair (dopri5) with a PI step controller via
``lax.while_loop``.

All fixed-step integrators are written as ``lax.scan`` over the mesh so the
whole solve lowers to ONE compact HLO while-loop — no per-step host round
trips on the request path (the rust coordinator executes the lowered graph
as a single PJRT call).

Vector fields have signature ``f(s, z) -> dz`` with ``s`` a scalar and ``z``
an arbitrary-shape f32 array (batched states included).
"""

from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import hyper_step as _hyper_step_kernel
from compile.kernels import rk_combine as _rk_combine_kernel
from compile.kernels.ref import hyper_step_ref, rk_combine_ref


class Tableau(NamedTuple):
    """Explicit Butcher tableau (strictly lower-triangular ``a``)."""

    name: str
    a: Tuple[Tuple[float, ...], ...]  # a[i] has i entries (stage i row)
    b: Tuple[float, ...]
    c: Tuple[float, ...]
    order: int
    # Embedded lower-order weights for error estimation (adaptive pairs).
    b_err: Optional[Tuple[float, ...]] = None

    @property
    def stages(self) -> int:
        return len(self.b)


EULER = Tableau("euler", a=((),), b=(1.0,), c=(0.0,), order=1)

MIDPOINT = Tableau(
    "midpoint", a=((), (0.5,)), b=(0.0, 1.0), c=(0.0, 0.5), order=2
)

HEUN = Tableau("heun", a=((), (1.0,)), b=(0.5, 0.5), c=(0.0, 1.0), order=2)

RK4 = Tableau(
    "rk4",
    a=((), (0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
    b=(1 / 6, 1 / 3, 1 / 3, 1 / 6),
    c=(0.0, 0.5, 0.5, 1.0),
    order=4,
)


def alpha_tableau(alpha: float) -> Tableau:
    """Second-order explicit alpha family (Fig. 5 right; Süli & Mayers).

    alpha = 0.5 recovers the midpoint method, alpha = 1.0 recovers Heun.
    """
    if alpha <= 0.0:
        raise ValueError("alpha must be positive")
    return Tableau(
        f"alpha{alpha:g}",
        a=((), (alpha,)),
        b=(1.0 - 1.0 / (2.0 * alpha), 1.0 / (2.0 * alpha)),
        c=(0.0, alpha),
        order=2,
    )


DOPRI5 = Tableau(
    "dopri5",
    a=(
        (),
        (1 / 5,),
        (3 / 40, 9 / 40),
        (44 / 45, -56 / 15, 32 / 9),
        (19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729),
        (9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656),
        (35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84),
    ),
    b=(35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0),
    c=(0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0),
    order=5,
    b_err=(
        5179 / 57600,
        0.0,
        7571 / 16695,
        393 / 640,
        -92097 / 339200,
        187 / 2100,
        1 / 40,
    ),
)

BY_NAME = {
    "euler": EULER,
    "midpoint": MIDPOINT,
    "heun": HEUN,
    "rk4": RK4,
    "dopri5": DOPRI5,
}


def solver_by_name(name: str) -> Tableau:
    """Resolve a tableau by name; 'alphaX.Y' builds the alpha family."""
    if name in BY_NAME:
        return BY_NAME[name]
    if name.startswith("alpha"):
        return alpha_tableau(float(name[len("alpha") :]))
    raise KeyError(f"unknown solver {name!r}")


# ---------------------------------------------------------------------------
# Fixed-step integration
# ---------------------------------------------------------------------------


def rk_stages(f: Callable, tab: Tableau, s, z, eps):
    """Compute the stage derivatives r_1..r_p of eq. (3)."""
    stages = []
    for i in range(tab.stages):
        zi = z
        for j, aij in enumerate(tab.a[i]):
            if aij != 0.0:
                zi = zi + (eps * aij) * stages[j]
        stages.append(f(s + tab.c[i] * eps, zi))
    return stages


def rk_update(f: Callable, tab: Tableau, s, z, eps, use_kernels: bool = False):
    """One explicit RK step z -> z_{+}. eps must be concrete if use_kernels."""
    stages = rk_stages(f, tab, s, z, eps)
    if use_kernels:
        return _rk_combine_kernel(z, jnp.stack(stages), tab.b, eps)
    return rk_combine_ref(
        z, jnp.stack(stages), jnp.array(tab.b, jnp.float32), eps
    )


def psi(f: Callable, tab: Tableau, s, z, eps):
    """The update direction ψ of eq. (2): (z_{+} - z)/eps as weighted stages."""
    stages = rk_stages(f, tab, s, z, eps)
    acc = jnp.zeros_like(z)
    for bi, ri in zip(tab.b, stages):
        if bi != 0.0:
            acc = acc + bi * ri
    return acc


def odeint_fixed(
    f: Callable,
    z0,
    s_span: Tuple[float, float],
    steps: int,
    tab: Tableau,
    use_kernels: bool = False,
    return_traj: bool = False,
):
    """Integrate ż = f(s, z) over ``s_span`` with K equal steps of ``tab``.

    Returns the terminal state, or the full (K+1, ...) trajectory when
    ``return_traj``. NFE = tab.stages * steps.
    """
    s0, s1 = s_span
    eps = (s1 - s0) / steps

    def body(z, k):
        s = s0 + k * eps
        z_next = rk_update(f, tab, s, z, eps, use_kernels=use_kernels)
        return z_next, z_next if return_traj else None

    ks = jnp.arange(steps, dtype=jnp.float32)
    z_final, traj = lax.scan(body, z0, ks)
    if return_traj:
        return jnp.concatenate([z0[None], traj], axis=0)
    return z_final


def odeint_hyper(
    f: Callable,
    g: Callable,
    z0,
    s_span: Tuple[float, float],
    steps: int,
    tab: Tableau,
    use_kernels: bool = True,
    return_traj: bool = False,
):
    """Hypersolved integration (eq. 5): base ψ plus ε^{p+1} g_ω correction.

    ``g(eps, s, z, dz)`` is the hypersolver network; ``dz = f(s, z)`` is the
    first RK stage (c_1 = 0 for every explicit method) so g reuses it for
    free — the correction costs one g_ω evaluation per step regardless of
    base order p, which is the paper's relative-overhead argument (§6).
    """
    s0, s1 = s_span
    eps = (s1 - s0) / steps
    step = _hyper_step_kernel if use_kernels else hyper_step_ref

    def body(z, k):
        s = s0 + k * eps
        stages = rk_stages(f, tab, s, z, eps)
        direction = jnp.zeros_like(z)
        for bi, ri in zip(tab.b, stages):
            if bi != 0.0:
                direction = direction + bi * ri
        corr = g(eps, s, z, stages[0])
        z_next = step(z, direction, corr, eps, tab.order)
        return z_next, z_next if return_traj else None

    ks = jnp.arange(steps, dtype=jnp.float32)
    z_final, traj = lax.scan(body, z0, ks)
    if return_traj:
        return jnp.concatenate([z0[None], traj], axis=0)
    return z_final


# ---------------------------------------------------------------------------
# Adaptive integration: Dormand-Prince 5(4) with PI controller
# ---------------------------------------------------------------------------


def odeint_dopri5(
    f: Callable,
    z0,
    s_span: Tuple[float, float],
    rtol: float = 1e-4,
    atol: float = 1e-4,
    max_steps: int = 10_000,
    safety: float = 0.9,
    min_factor: float = 0.2,
    max_factor: float = 10.0,
):
    """Adaptive Dormand-Prince 5(4) via ``lax.while_loop``.

    Returns ``(z_final, nfe)`` where nfe counts vector-field evaluations
    (7 per attempted step; no FSAL reuse, matching torchdiffeq's count
    conventions closely enough for the paper's comparisons).

    The whole loop lowers to HLO, so the rust runtime can run dopri5 as a
    single PJRT execution — this is the paper's baseline on the serving
    path. Error control: mixed abs/rel norm, max-norm across the batch so
    one step size serves the whole batch; PI-flavoured step adaptation with
    the standard 1/(order) exponent and safety clamps.
    """
    s0, s1 = s_span
    tab = DOPRI5
    direction = 1.0 if s1 >= s0 else -1.0
    span = abs(s1 - s0)

    def err_norm(z_new, z_err, z_old):
        scale = atol + rtol * jnp.maximum(jnp.abs(z_new), jnp.abs(z_old))
        return jnp.sqrt(jnp.mean((z_err / scale) ** 2))

    def attempt(s, z, eps):
        # ``s`` is progress in [0, span]; map to absolute integration time.
        s_abs = s0 + direction * s
        stages = rk_stages(f, tab, s_abs, z, direction * eps)
        acc5 = jnp.zeros_like(z)
        acc4 = jnp.zeros_like(z)
        for b5, b4, r in zip(tab.b, tab.b_err, stages):
            if b5 != 0.0:
                acc5 = acc5 + b5 * r
            if b4 != 0.0:
                acc4 = acc4 + b4 * r
        z5 = z + direction * eps * acc5
        z4 = z + direction * eps * acc4
        return z5, z5 - z4

    def cond(state):
        s, z, eps, nfe, done, iters = state
        return jnp.logical_and(jnp.logical_not(done), iters < max_steps)

    def body(state):
        s, z, eps, nfe, done, iters = state
        remaining = span - s
        eps_c = jnp.minimum(eps, remaining)
        z_new, z_err = attempt(s, z, eps_c)
        err = err_norm(z_new, z_err, z)
        accept = err <= 1.0
        # step-size update (elementary PI: exponent 1/5, safety-clamped)
        factor = safety * (jnp.maximum(err, 1e-10)) ** (-0.2)
        factor = jnp.clip(factor, min_factor, max_factor)
        eps_next = jnp.clip(eps_c * factor, 1e-6 * span, span)
        s_next = jnp.where(accept, s + eps_c, s)
        z_next = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), z_new, z
        )
        done_next = s_next >= span * (1.0 - 1e-9)
        return (s_next, z_next, eps_next, nfe + tab.stages, done_next, iters + 1)

    init = (
        jnp.float32(0.0),
        z0,
        jnp.float32(span / 10.0),
        jnp.int32(0),
        jnp.bool_(False),
        jnp.int32(0),
    )
    s, z, eps, nfe, done, iters = lax.while_loop(cond, body, init)
    return z, nfe


def dopri5_mesh(
    f: Callable,
    z0,
    s_grid: Sequence[float],
    rtol: float = 1e-5,
    atol: float = 1e-5,
):
    """Ground-truth solution checkpoints z(s_k) on a mesh (paper §3.2).

    Integrates segment-by-segment with dopri5 so every mesh point is an
    accurately resolved state; returns the (K+1, ...) stacked trajectory
    used as the hypersolver training set.
    """
    zs = [z0]
    z = z0
    for lo, hi in zip(s_grid[:-1], s_grid[1:]):
        z, _ = odeint_dopri5(f, z, (float(lo), float(hi)), rtol, atol)
        zs.append(z)
    return jnp.stack(zs)
