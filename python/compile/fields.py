"""Neural ODE vector fields and model heads (Layer 2).

Functional style: parameters are pytrees (nested dicts/lists), every
``*_apply`` is pure so the whole model jits / grads / lowers cleanly.

Fields implemented:
  - MLP field (CNF, tracking): ``f(s, z) = MLP([z, timefeat(s)])`` with
    either raw-time concat or a truncated Fourier basis of s ("Galerkin"
    style depth variance, Massaroli et al. 2020b).
  - Conv field (image classification): input-layer augmented conv field
    with DepthCat (s appended as a constant channel), matching the paper's
    appendix C.2 architecture shape at CPU-friendly widths.

The MLP hot path dispatches to the Pallas ``fused_linear_act`` kernel when
``use_kernels`` and the problem is big enough (see kernels/linear_act.py).
"""

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import fused_linear_act
from compile.kernels.ref import act, linear_act_ref

Params = Dict


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def init_linear(key, n_in: int, n_out: int) -> Params:
    """Kaiming-ish fan-in init for a dense layer."""
    wkey, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(n_in)
    return {
        "w": jax.random.normal(wkey, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def init_mlp(key, sizes: Sequence[int]) -> List[Params]:
    """Stack of dense layers; sizes = [in, h1, ..., out]."""
    keys = jax.random.split(key, len(sizes) - 1)
    return [
        init_linear(k, a, b) for k, a, b in zip(keys, sizes[:-1], sizes[1:])
    ]


def init_conv(key, c_in: int, c_out: int, ksize: int) -> Params:
    """Kaiming fan-in init for a 2-D conv (NCHW / OIHW)."""
    scale = 1.0 / jnp.sqrt(c_in * ksize * ksize)
    return {
        "w": jax.random.normal(key, (c_out, c_in, ksize, ksize), jnp.float32)
        * scale,
        "b": jnp.zeros((c_out,), jnp.float32),
    }


def init_prelu(c: int) -> Params:
    return {"alpha": jnp.full((c,), 0.25, jnp.float32)}


# ---------------------------------------------------------------------------
# Primitive applies
# ---------------------------------------------------------------------------


def linear_apply(p: Params, x, kind: str = "id", use_kernels: bool = False):
    """act(x @ w + b); kernel-dispatched when requested."""
    if use_kernels:
        return fused_linear_act(x, p["w"], p["b"], kind)
    return linear_act_ref(x, p["w"], p["b"], kind)


def mlp_apply(
    layers: List[Params],
    x,
    hidden_act: str = "tanh",
    out_act: str = "id",
    use_kernels: bool = False,
):
    for p in layers[:-1]:
        x = linear_apply(p, x, hidden_act, use_kernels)
    return linear_apply(layers[-1], x, out_act, use_kernels)


def conv_apply(p: Params, x, padding: str = "SAME"):
    """NCHW conv + bias."""
    out = lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(1, 1),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + p["b"][None, :, None, None]


def prelu_apply(p: Params, x):
    """Channelwise PReLU (NCHW)."""
    a = p["alpha"][None, :, None, None]
    return jnp.where(x >= 0, x, a * x)


# ---------------------------------------------------------------------------
# Time features
# ---------------------------------------------------------------------------


def time_features(s, mode: str):
    """Depth features appended to the field input.

    ``concat``  -> [s]
    ``fourier3``-> [sin/cos(2πks), k=1..3] (Galerkin-flavoured depth basis)
    """
    s = jnp.asarray(s, jnp.float32)
    if mode == "concat":
        return jnp.reshape(s, (1,))
    if mode == "fourier3":
        ks = jnp.arange(1, 4, dtype=jnp.float32)
        ang = 2.0 * jnp.pi * ks * s
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
    raise ValueError(f"unknown time mode {mode!r}")


TIME_FEAT_DIM = {"concat": 1, "fourier3": 6}


# ---------------------------------------------------------------------------
# MLP vector field (CNF / tracking)
# ---------------------------------------------------------------------------


def init_mlp_field(
    key, state_dim: int, hidden: Sequence[int], time_mode: str = "concat"
) -> Params:
    # time_mode is static config, NOT part of the param pytree (optimisers
    # tree_map over params, so leaves must all be arrays).
    sizes = [state_dim + TIME_FEAT_DIM[time_mode], *hidden, state_dim]
    return {"layers": init_mlp(key, sizes)}


def mlp_field_apply(
    params: Params, s, z, time_mode: str = "concat", use_kernels: bool = False
):
    """f(s, z) for batched z of shape (B, D)."""
    feats = time_features(s, time_mode)
    feats = jnp.broadcast_to(feats, (z.shape[0], feats.shape[0]))
    x = jnp.concatenate([z, feats], axis=1)
    return mlp_apply(
        params["layers"], x, hidden_act="tanh", out_act="id",
        use_kernels=use_kernels,
    )


# ---------------------------------------------------------------------------
# Conv vector field + classification heads (images)
# ---------------------------------------------------------------------------


def depth_cat(s, x):
    """Append s as a constant channel (paper's DepthCat)."""
    b, _, h, w = x.shape
    sc = jnp.full((b, 1, h, w), jnp.asarray(s, jnp.float32))
    return jnp.concatenate([x, sc], axis=1)


def init_conv_field(key, aug_ch: int, hidden_ch: int) -> Params:
    """DepthCat conv field: (aug+1 -> hidden) tanh (hidden+1 -> hidden) tanh
    (hidden -> aug), all 3x3 SAME — the appendix C.2 shape."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "c1": init_conv(k1, aug_ch + 1, hidden_ch, 3),
        "c2": init_conv(k2, hidden_ch + 1, hidden_ch, 3),
        "c3": init_conv(k3, hidden_ch, aug_ch, 3),
    }


def conv_field_apply(params: Params, s, z):
    """f(s, z) for NCHW states z of shape (B, aug_ch, H, W)."""
    x = depth_cat(s, z)
    x = jnp.tanh(conv_apply(params["c1"], x))
    x = depth_cat(s, x)
    x = jnp.tanh(conv_apply(params["c2"], x))
    return conv_apply(params["c3"], x)


def init_image_model(
    key, in_ch: int, aug_ch: int, hidden_ch: int, hw: int, n_classes: int
) -> Params:
    """Augmenter h_x (conv in->aug), conv field, head h_y (conv aug->1,
    flatten, linear)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "hx": init_conv(k1, in_ch, aug_ch, 3),
        "field": init_conv_field(k2, aug_ch, hidden_ch),
        "hy_conv": init_conv(k3, aug_ch, 1, 3),
        "hy_lin": init_linear(k4, hw * hw, n_classes),
    }


def image_hx_apply(params: Params, x_img):
    """Input augmentation: images (B, in_ch, H, W) -> state (B, aug, H, W)."""
    return conv_apply(params["hx"], x_img)


def image_hy_apply(params: Params, z):
    """Readout: terminal state -> logits (B, n_classes)."""
    b = z.shape[0]
    feat = conv_apply(params["hy_conv"], z).reshape(b, -1)
    return linear_act_ref(feat, params["hy_lin"]["w"], params["hy_lin"]["b"], "id")


# ---------------------------------------------------------------------------
# Hypersolver networks g_ω
# ---------------------------------------------------------------------------


def init_hyper_mlp(key, state_dim: int, hidden: Sequence[int]) -> Params:
    """g_ω for flat states: input [z, dz, eps, s] (appendix B.1 template)."""
    sizes = [2 * state_dim + 2, *hidden, state_dim]
    return {"layers": init_mlp(key, sizes)}


def hyper_mlp_apply(params: Params, eps, s, z, dz, use_kernels: bool = False):
    b = z.shape[0]
    eps_col = jnp.full((b, 1), jnp.asarray(eps, jnp.float32))
    s_col = jnp.broadcast_to(jnp.asarray(s, jnp.float32), (b, 1))
    x = jnp.concatenate([z, dz, eps_col, s_col], axis=1)
    return mlp_apply(
        params["layers"], x, hidden_act="tanh", out_act="id",
        use_kernels=use_kernels,
    )


def init_hyper_cnn(key, aug_ch: int, hidden_ch: int) -> Params:
    """2-layer PReLU CNN g_ω: input cat(z, dz, s) channels (appendix C.2)."""
    k1, k2 = jax.random.split(key, 2)
    return {
        "c1": init_conv(k1, 2 * aug_ch + 1, hidden_ch, 3),
        "p1": init_prelu(hidden_ch),
        "c2": init_conv(k2, hidden_ch, aug_ch, 3),
    }


def hyper_cnn_apply(params: Params, eps, s, z, dz):
    # ds enters as a constant channel scaled by eps (the template's
    # ds*ones concat); s is folded into the same channel via s + eps.
    x = jnp.concatenate([z, dz], axis=1)
    x = depth_cat(jnp.asarray(s, jnp.float32) + jnp.asarray(eps, jnp.float32), x)
    x = prelu_apply(params["p1"], conv_apply(params["c1"], x))
    return conv_apply(params["c2"], x)


# ---------------------------------------------------------------------------
# Optimiser (no optax in this environment: minimal AdamW)
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": jnp.int32(0)}


def adamw_update(
    grads,
    state,
    params,
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One AdamW step; returns (new_params, new_state). ``lr`` may be a
    traced scalar (cosine schedules are closed over by the train loop)."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1.0 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1.0 - b2 ** t.astype(jnp.float32))

    def upd(p, m_, v_):
        step = lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
        return p - step - lr * weight_decay * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total: int, lr0: float, lr1: float):
    """Cosine annealing lr0 -> lr1 over ``total`` steps."""
    frac = jnp.clip(step.astype(jnp.float32) / total, 0.0, 1.0)
    return lr1 + 0.5 * (lr0 - lr1) * (1.0 + jnp.cos(jnp.pi * frac))
