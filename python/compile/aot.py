"""AOT build entrypoint: train everything, export every artifact.

``make artifacts`` → ``python -m compile.aot --out ../artifacts``.

Python runs ONCE here and never again: the rust coordinator is fully
self-contained after this script writes

  artifacts/<task>_<variant>.hlo.txt   full-solve executables (HLO text)
  artifacts/<task>_field.hlo.txt       single f-eval (rust-driven dopri5)
  artifacts/weights/<task>.json        raw weights (native rust nn path)
  artifacts/data/<task>_*.bin          eval batches + dopri5 ground truth
  artifacts/manifest.json              the registry the rust side loads

Incremental: a content stamp over python/compile/**.py is stored in the
manifest; when it matches, the build is a no-op.

``--quick`` shrinks every iteration count ~20× (used by pytest to exercise
the full export path in seconds; quality is NOT representative).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from compile import export as E
from compile import fields as F
from compile import macs as M
from compile import solvers as S
from compile.tasks import cnf as C
from compile.tasks import images as I
from compile.tasks import tracking as T

SEED = 0


def stamp_sources() -> str:
    """Content hash of every python source feeding the artifacts."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def mape(pred, truth) -> float:
    """Mean absolute percentage error with the paper's small-denominator
    guard (terminal-state MAPE, §C.2)."""
    p = np.asarray(pred).reshape(-1)
    t = np.asarray(truth).reshape(-1)
    return float(np.mean(np.abs(p - t) / (np.abs(t) + 1e-2)))


# ---------------------------------------------------------------------------
# Generic variant exporter
# ---------------------------------------------------------------------------


def export_variants(
    out_dir,
    task_name,
    f,
    g,
    z0_eval,
    truth,
    s_span,
    fixed_grid,
    hyper_grid,
    hyper_tab,
    mac_f,
    mac_g,
    use_kernels,
    dopri_tol=1e-4,
    extra_metric=None,
):
    """Export full-solve HLOs for a (solver, K) grid plus dopri5; measure
    terminal MAPE of each variant against ``truth`` on the eval batch.

    fixed_grid: list of (solver_name, K); hyper_grid: list of K for the
    hypersolved variant with base ``hyper_tab``. Returns manifest entries.
    """
    variants = []
    B = z0_eval.shape[0]

    def emit(name, fn, nfe, macs_total, solver, k, hyper):
        path = os.path.join(out_dir, f"{task_name}_{name}.hlo.txt")
        E.export_fn(fn, (z0_eval,), path)
        zT = jax.jit(fn)(z0_eval)
        if isinstance(zT, tuple):
            zT = zT[0]
        ent = {
            "name": name,
            "solver": solver,
            "k": k,
            "hyper": hyper,
            "hlo": os.path.basename(path),
            "nfe": nfe,
            "macs": macs_total,
            "mape": mape(zT, truth),
            "in_shape": list(z0_eval.shape),
            "out_shape": list(np.asarray(zT).shape),
        }
        if extra_metric is not None:
            ent.update(extra_metric(zT))
        variants.append(ent)

    for sname, k in fixed_grid:
        tab = S.solver_by_name(sname)
        fn = lambda z, tab=tab, k=k: S.odeint_fixed(
            f, z, s_span, k, tab, use_kernels=use_kernels
        )
        emit(
            f"{sname}_k{k}", fn, tab.stages * k,
            M.solve_macs(mac_f, mac_g, tab.stages, k, False), sname, k, False,
        )

    for k in hyper_grid:
        fn = lambda z, k=k: S.odeint_hyper(
            f, g, z, s_span, k, hyper_tab, use_kernels=use_kernels
        )
        emit(
            f"hyper{hyper_tab.name}_k{k}", fn, hyper_tab.stages * k,
            M.solve_macs(mac_f, mac_g, hyper_tab.stages, k, True),
            hyper_tab.name, k, True,
        )

    # adaptive baseline: whole dopri5 loop in one HLO (returns (z, nfe))
    def dopri_fn(z):
        return S.odeint_dopri5(f, z, s_span, dopri_tol, dopri_tol)

    path = os.path.join(out_dir, f"{task_name}_dopri5.hlo.txt")
    E.export_fn(dopri_fn, (z0_eval,), path)
    zT, nfe = jax.jit(dopri_fn)(z0_eval)
    ent = {
        "name": "dopri5",
        "solver": "dopri5",
        "k": 0,
        "hyper": False,
        "hlo": os.path.basename(path),
        "nfe": int(nfe),
        "macs": int(nfe) * mac_f,
        "mape": mape(zT, truth),
        "in_shape": list(z0_eval.shape),
        "out_shape": list(np.asarray(zT).shape),
        "outputs": ["z", "nfe"],
    }
    if extra_metric is not None:
        ent.update(extra_metric(zT))
    variants.append(ent)

    # single f evaluation: drives the rust-native adaptive solver
    field_path = os.path.join(out_dir, f"{task_name}_field.hlo.txt")
    E.export_fn(lambda s, z: f(s[0], z), (jnp.zeros((1,), jnp.float32), z0_eval), field_path)
    return variants


# ---------------------------------------------------------------------------
# CNF tasks
# ---------------------------------------------------------------------------


def build_cnf(out_dir, quick, density, key):
    t0 = time.time()
    iters = 30 if quick else 500
    hiters = 40 if quick else 1200
    params, nll = C.train_cnf(key, density, iters=iters)
    hkey = jax.random.fold_in(key, 1)
    hparams, delta = C.fit_hyperheun(hkey, params, iters=hiters)
    name = f"cnf_{density}"

    B = 256
    rng = np.random.default_rng(42)
    z0 = jnp.asarray(rng.normal(size=(B, 2)), jnp.float32)
    f = lambda s, z: C.cnf_field(params, s, z, use_kernels=False)
    fk = lambda s, z: C.cnf_field(params, s, z, use_kernels=True)
    g = lambda e, s, z, dz: C.hyper_apply(hparams, e, s, z, dz)
    truth, _ = jax.jit(
        lambda z: S.odeint_dopri5(f, z, C.S_SPAN, 1e-6, 1e-6)
    )(z0)

    mac_f = M.mlp_field_macs(2, C.CNF_HIDDEN, 1)
    mac_g = M.hyper_mlp_macs(2, C.HYPER_HIDDEN)
    fixed = [
        ("euler", 1), ("euler", 2), ("euler", 4), ("euler", 8), ("euler", 16),
        ("midpoint", 1), ("midpoint", 2), ("midpoint", 4), ("midpoint", 8),
        ("heun", 1), ("heun", 2), ("heun", 4), ("heun", 8),
        ("rk4", 1), ("rk4", 2), ("rk4", 4),
    ]
    variants = export_variants(
        out_dir, name, fk, g, z0, truth, C.S_SPAN,
        fixed, [1, 2, 4], S.HEUN, mac_f, mac_g, use_kernels=True,
        dopri_tol=1e-5,
    )

    # weights for the native rust path
    E.write_json(
        {
            "kind": "cnf",
            "field": {
                "type": "mlp_field",
                "time_mode": "concat",
                "layers": E.mlp_json(params["layers"]),
            },
            "hyper": {
                "type": "hyper_mlp",
                "layers": E.mlp_json(hparams["layers"]),
            },
        },
        os.path.join(out_dir, "weights", f"{name}.json"),
    )
    data = {
        "z0": E.write_f32(z0, os.path.join(out_dir, "data", f"{name}_z0.bin")),
        "truth": E.write_f32(
            truth, os.path.join(out_dir, "data", f"{name}_truth.bin")
        ),
        "density_samples": E.write_f32(
            C.sample_density(density, 2000, np.random.default_rng(7)),
            os.path.join(out_dir, "data", f"{name}_density.bin"),
        ),
    }
    print(f"[aot] {name}: nll={nll:.3f} delta={delta:.4f} "
          f"({time.time()-t0:.0f}s)")
    return name, {
        "kind": "cnf",
        "state": {"shape": [B, 2]},
        "s_span": list(C.S_SPAN),
        "weights": f"weights/{name}.json",
        "field_hlo": f"{name}_field.hlo.txt",
        "macs": {"field": mac_f, "hyper": mac_g},
        "delta": delta,
        "train_nll": nll,
        "variants": variants,
        "data": data,
        "hyper_base": "heun",
    }


# ---------------------------------------------------------------------------
# Image tasks
# ---------------------------------------------------------------------------


def build_images(out_dir, quick, ds, key, with_hypermidpoint=False):
    t0 = time.time()
    iters = 20 if quick else 250
    hiters = 20 if quick else 400
    params, loss = I.train_model(key, ds, iters=iters)
    hkey = jax.random.fold_in(key, 1)
    hparams, delta = I.fit_hyper(hkey, params, ds, iters=hiters)
    name = f"img_{ds}"
    ch = I.DATASETS[ds]

    B = 64
    rng = np.random.default_rng(123)
    x_eval, y_eval = I.make_dataset(ds, B, rng)
    x_eval = jnp.asarray(x_eval)
    z0 = F.image_hx_apply(params, x_eval)
    f = lambda s, z: I.field(params, s, z)
    g = lambda e, s, z, dz: F.hyper_cnn_apply(hparams, e, s, z, dz)
    truth, _ = jax.jit(
        lambda z: S.odeint_dopri5(f, z, I.S_SPAN, 1e-6, 1e-6)
    )(z0)
    truth_logits = F.image_hy_apply(params, truth)
    truth_acc = I.accuracy(truth_logits, jnp.asarray(y_eval))

    def extra(zT):
        # task metric: accuracy decrement vs the dopri5 reference (§C.2)
        if zT.ndim != truth.ndim:
            return {}
        logits = F.image_hy_apply(params, zT)
        acc = I.accuracy(logits, jnp.asarray(y_eval))
        return {"acc": acc, "acc_drop": truth_acc - acc}

    mac_f = M.conv_field_macs(I.AUG_CH, I.HIDDEN_CH, I.HW)
    mac_g = M.hyper_cnn_macs(I.AUG_CH, I.HYPER_CH, I.HW)
    fixed = [
        ("euler", 1), ("euler", 2), ("euler", 4), ("euler", 8), ("euler", 16),
        ("midpoint", 1), ("midpoint", 2), ("midpoint", 4), ("midpoint", 8),
        ("rk4", 1), ("rk4", 2), ("rk4", 4),
    ]
    variants = export_variants(
        out_dir, name, f, g, z0, truth, I.S_SPAN,
        fixed, [1, 2, 4, 8], S.EULER, mac_f, mac_g, use_kernels=False,
        dopri_tol=1e-4, extra_metric=extra,
    )

    # classification end-to-end executables (image -> logits) for serving
    for sname, k, hyper in [("euler", 2, True), ("euler", 8, False),
                            ("rk4", 4, False)]:
        tag = ("hyper" if hyper else "") + f"{sname}_k{k}_logits"
        fn = (
            (lambda x: I.classify_hyper(params, hparams, x, k, S.EULER))
            if hyper
            else (lambda x: I.classify(params, x, k, S.solver_by_name(sname)))
        )
        E.export_fn(fn, (x_eval,), os.path.join(out_dir, f"{name}_{tag}.hlo.txt"))

    wjson = {
        "kind": "image",
        "hw": I.HW,
        "in_ch": ch,
        "aug_ch": I.AUG_CH,
        "hx": E.conv_json(params["hx"]),
        "field": {
            "type": "conv_field",
            "c1": E.conv_json(params["field"]["c1"]),
            "c2": E.conv_json(params["field"]["c2"]),
            "c3": E.conv_json(params["field"]["c3"]),
        },
        "hy_conv": E.conv_json(params["hy_conv"]),
        "hy_lin": E.linear_json(params["hy_lin"], "id"),
        "hyper": {
            "type": "hyper_cnn",
            "c1": E.conv_json(hparams["c1"]),
            "p1": E.prelu_json(hparams["p1"]),
            "c2": E.conv_json(hparams["c2"]),
        },
    }
    entry = {
        "kind": "image",
        "state": {"shape": [B, I.AUG_CH, I.HW, I.HW]},
        "s_span": list(I.S_SPAN),
        "weights": f"weights/{name}.json",
        "field_hlo": f"{name}_field.hlo.txt",
        "macs": {"field": mac_f, "hyper": mac_g},
        "delta": delta,
        "truth_acc": truth_acc,
        "variants": variants,
        "hyper_base": "euler",
    }

    if with_hypermidpoint:
        # HyperMidpoint for the α-family generalization experiment (Fig 6)
        hm_key = jax.random.fold_in(key, 2)
        hm_params, hm_delta = I.fit_hyper(
            hm_key, params, ds, tab=S.MIDPOINT, iters=hiters
        )
        wjson["hyper_midpoint"] = {
            "type": "hyper_cnn",
            "c1": E.conv_json(hm_params["c1"]),
            "p1": E.prelu_json(hm_params["p1"]),
            "c2": E.conv_json(hm_params["c2"]),
        }
        entry["hyper_midpoint_delta"] = hm_delta

    E.write_json(wjson, os.path.join(out_dir, "weights", f"{name}.json"))
    entry["data"] = {
        "x": E.write_f32(x_eval, os.path.join(out_dir, "data", f"{name}_x.bin")),
        "y": E.write_i32(y_eval, os.path.join(out_dir, "data", f"{name}_y.bin")),
        "z0": E.write_f32(z0, os.path.join(out_dir, "data", f"{name}_z0.bin")),
        "truth": E.write_f32(
            truth, os.path.join(out_dir, "data", f"{name}_truth.bin")
        ),
    }
    print(f"[aot] {name}: train_loss={loss:.3f} acc*={truth_acc:.3f} "
          f"delta={delta:.4f} ({time.time()-t0:.0f}s)")
    return name, entry


# ---------------------------------------------------------------------------
# Tracking task
# ---------------------------------------------------------------------------


def build_tracking(out_dir, quick, key):
    t0 = time.time()
    iters = 20 if quick else 400
    hiters = 30 if quick else 800
    params, loss = T.train_tracker(key, iters=iters)
    hkey = jax.random.fold_in(key, 1)
    hparams, delta = T.fit_hyper(hkey, params, iters=hiters)
    name = "tracking"

    B = 64
    rng = np.random.default_rng(21)
    z0 = jnp.asarray(
        np.asarray(T.beta(0.0))[None, :] + 0.3 * rng.normal(size=(B, 2)),
        jnp.float32,
    )
    f = lambda s, z: T.field(params, s, z)
    g = lambda e, s, z, dz: T.hyper_apply(hparams, e, s, z, dz)
    truth, _ = jax.jit(
        lambda z: S.odeint_dopri5(f, z, T.S_SPAN, 1e-6, 1e-6)
    )(z0)

    mac_f = M.mlp_field_macs(2, T.FIELD_HIDDEN, 6)
    mac_g = M.hyper_mlp_macs(2, T.HYPER_HIDDEN)
    fixed = [
        ("euler", 5), ("euler", 10), ("euler", 25), ("euler", 50),
        ("midpoint", 5), ("midpoint", 10), ("midpoint", 25),
        ("rk4", 2), ("rk4", 5), ("rk4", 10),
    ]
    variants = export_variants(
        out_dir, name, f, g, z0, truth, T.S_SPAN,
        fixed, [5, 10, 25], S.EULER, mac_f, mac_g, use_kernels=False,
        dopri_tol=1e-5,
    )

    # dense ground-truth mesh for the global-error (Fig 8) bench
    s_grid = np.linspace(T.S_SPAN[0], T.S_SPAN[1], 26)
    mesh = jax.jit(lambda z: S.dopri5_mesh(f, z, list(s_grid), 1e-6, 1e-6))(z0)

    E.write_json(
        {
            "kind": "tracking",
            "field": {
                "type": "mlp_field",
                "time_mode": "fourier3",
                "layers": E.mlp_json(params["layers"]),
            },
            "hyper": {
                "type": "hyper_mlp",
                "layers": E.mlp_json(hparams["layers"]),
            },
        },
        os.path.join(out_dir, "weights", f"{name}.json"),
    )
    data = {
        "z0": E.write_f32(z0, os.path.join(out_dir, "data", f"{name}_z0.bin")),
        "truth": E.write_f32(
            truth, os.path.join(out_dir, "data", f"{name}_truth.bin")
        ),
        "mesh": E.write_f32(
            mesh, os.path.join(out_dir, "data", f"{name}_mesh.bin")
        ),
    }
    print(f"[aot] {name}: loss={loss:.4f} delta={delta:.4f} "
          f"({time.time()-t0:.0f}s)")
    return name, {
        "kind": "tracking",
        "state": {"shape": [B, 2]},
        "s_span": list(T.S_SPAN),
        "weights": f"weights/{name}.json",
        "field_hlo": f"{name}_field.hlo.txt",
        "macs": {"field": mac_f, "hyper": mac_g},
        "delta": delta,
        "variants": variants,
        "data": data,
        "hyper_base": "euler",
    }


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny iteration counts (pytest path exercise only)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated task subset, e.g. cnf_rings,img_smnist",
    )
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    stamp = stamp_sources() + ("-quick" if args.quick else "")

    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            old = json.load(fh)
        if (
            old.get("stamp") == stamp
            and args.only is None
            and not old.get("partial", False)
        ):
            print(f"[aot] artifacts up to date (stamp {stamp}); skipping")
            return

    only = set(args.only.split(",")) if args.only else None
    t0 = time.time()
    key = jax.random.PRNGKey(SEED)
    tasks = {}

    builders = []
    for i, d in enumerate(C.DENSITIES):
        builders.append(
            (f"cnf_{d}", lambda d=d, i=i: build_cnf(
                out_dir, args.quick, d, jax.random.fold_in(key, 10 + i)))
        )
    builders.append(
        ("img_smnist", lambda: build_images(
            out_dir, args.quick, "smnist", jax.random.fold_in(key, 20),
            with_hypermidpoint=True))
    )
    builders.append(
        ("img_scifar", lambda: build_images(
            out_dir, args.quick, "scifar", jax.random.fold_in(key, 21)))
    )
    builders.append(
        ("tracking", lambda: build_tracking(
            out_dir, args.quick, jax.random.fold_in(key, 30)))
    )

    for tname, build in builders:
        if only is not None and tname not in only:
            continue
        name, entry = build()
        tasks[name] = entry

    # merge with an existing manifest when --only rebuilt a subset
    if only is not None and os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            old = json.load(fh)
        merged = old.get("tasks", {})
        merged.update(tasks)
        tasks = merged

    manifest = {
        "version": 1,
        "stamp": stamp,
        "seed": SEED,
        "quick": args.quick,
        "partial": only is not None,
        "tasks": tasks,
    }
    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"[aot] wrote {manifest_path} ({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
